//! Native W4A4G4 training state + step loop (the Eq. 3/6 splits on the
//! training hot path, paper §3).
//!
//! The quantize-model pipeline proved the splits cheap and accurate on
//! frozen checkpoints; this module puts them where the paper claims
//! they belong — inside the step loop:
//!
//! * **Init-time Eq. 3 packing** — every 2-D parameter is decomposed
//!   once through the configured [`DecompStrategy`] and held as a
//!   [`PackedWeight`]: quantized factors Q(U), Q(Vᵀ), Q(W_R) plus the
//!   high-precision spectrum S and a high-precision master copy the
//!   optimizer updates.  After each update the packing is *refreshed*
//!   against the frozen init-time basis (a cheap O(mnk) projection),
//!   or fully re-decomposed every `repack_every` steps.
//! * **Per-step Eq. 6 gradient splits** — a [`GradStep`] runs each raw
//!   layer gradient through the randomized split D = P T Qᵀ + D_R, the
//!   §3.2 adaptive spectral rescale ([`crate::metis::lr`]), and
//!   sub-distribution quantization ([`quantize_grad_split`]) before the
//!   optimizer sees it.
//! * **Sharded, deterministic stepping** — [`TrainState::step_with`]
//!   fans layers across a scoped worker pool (the pipeline's
//!   work-queue idiom); every (layer, step) draws from its own
//!   `fold_in`-derived stream, so loss curves are bit-identical for any
//!   thread count.
//!
//! [`train_native`] drives the whole loop over a synthetic model with a
//! quantized-activation regression objective — the W4A4G4 path is
//! demonstrable today under the offline `xla` stub, and the same
//! `GradStep`/`TrainState` pair is the hook `coordinator::trainer`
//! (see `Trainer::pack_weights`) will feed real PJRT gradients through
//! once artifacts expose them.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::schedule::Schedule;
use crate::formats::{quantize_matrix_along, Format, PackedQMatrix};
use crate::metis::eval::{EvalReport, EvalState};
use crate::metis::lr::rescale_stats;
use crate::metis::pipeline::{column_blocks, synthetic_model, Layer, LayerSource, LayerSpec};
use crate::metis::quantizer::{quantize_grad_split, MetisQuantConfig};
use crate::metis::split::{gradient_split, weight_split};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::npy::ReaderCache;
use crate::util::prng::Rng;
use crate::util::timer::Stopwatch;
use crate::util::workpool::WorkPool;

/// Stream-domain tags keeping the trainstate RNG streams disjoint from
/// `synthetic_model`'s `fold_in(i)` and the pipeline's
/// `fold_in(i).fold_in(u64::MAX)` layer streams.
const PACK_DOMAIN: u64 = 0x4d45_5449_5350_4143; // "METISPAC"
const STEP_DOMAIN: u64 = 0x4d45_5449_5353_5445; // "METISSTE"
const TARGET_DOMAIN: u64 = 0x4d45_5449_5354_4152; // "METISTAR"
/// Sub-domain of a layer's pack stream for its column blocks (only
/// multi-block layers use it — single-block layers keep the historical
/// per-layer stream, so unblocked packings stay bit-identical to
/// earlier releases).
const PACK_BLOCK_DOMAIN: u64 = 0x4d45_5449_5350_424b; // "METISPBK"

/// The RNG stream an init-time Eq. 3 packing draws from: the layer's
/// `fold_in` stream for single-block layers (the historical layout), a
/// per-(layer, block) sub-stream otherwise.  One function shared by
/// [`TrainState::init_specs`] and the eval harness's pack-on-the-fly
/// path, so `metis eval <ckpt>` measures exactly the packing
/// `train-native` would start from at the same seed.
pub(crate) fn pack_stream(seed: u64, layer: usize, block: usize, single: bool) -> Rng {
    let layer_stream = Rng::new(seed).fold_in(PACK_DOMAIN).fold_in(layer as u64);
    if single {
        layer_stream
    } else {
        layer_stream
            .fold_in(PACK_BLOCK_DOMAIN)
            .fold_in(block as u64)
    }
}

/// One column block of a packed weight: W_b ≈ Q(U_b) S_b Q(V_bᵀ) with
/// the block residual folded into the cached effective weight.  S stays
/// high-precision (Eq. 5 exempts it).  The factors are held in *packed*
/// nibble form ([`PackedQMatrix`], ISSUE 9) — a quarter the resident
/// bytes of the former dense f64 copies — and refresh/repack contract
/// them through `linalg::qgemm` without ever re-materializing them.
pub struct PackedBlock {
    /// First column of this block within the layer.
    pub c0: usize,
    /// Quantized left factor Q(U), m×k, packed along axis 0.
    pub uq: PackedQMatrix,
    /// High-precision spectrum of the block split.
    pub s: Vec<f64>,
    /// Quantized right factor Q(Vᵀ), k×width, packed along axis 0.
    pub vtq: PackedQMatrix,
}

impl PackedBlock {
    /// Column count of the block.
    pub fn width(&self) -> usize {
        self.vtq.cols
    }
}

/// Eq. 3 split + Eq. 5 quantization of one column block, returning the
/// frozen-basis factors and the effective block Q(U) S Q(Vᵀ) + Q(W_R)
/// (the residual is not stored: refresh/repack recompute it from the
/// master, so keeping it would only double the resident footprint).
fn pack_block(
    wb: &Matrix,
    c0: usize,
    quant: &MetisQuantConfig,
    rng: &mut Rng,
) -> (PackedBlock, Matrix) {
    let k = quant.rank(wb.min_dim());
    let split = weight_split(wb, k, quant.strategy, rng);
    let (uq, vtq, rq) = crate::metis::quantizer::pack_split_parts(&split, quant.fmt);
    // Factor payload actually produced by this packing: nibble codes +
    // block scales of Q(U)/Q(Vᵀ) plus the f64 spectrum — the true 4-bit
    // resident footprint (the residual lives only in the effective
    // cache).
    crate::obs::metrics::metrics().packed_bytes.add(
        (uq.packed_bytes() + 8 * split.svd.s.len() + vtq.packed_bytes()) as u64,
    );
    let eff = crate::linalg::qgemm_scaled(&uq, &split.svd.s, &vtq).add(&rq.unpack());
    (
        PackedBlock {
            c0,
            uq,
            s: split.svd.s,
            vtq,
        },
        eff,
    )
}

/// One parameter matrix in packed Eq. 3 form, per column block:
/// W ≈ [Q(U_b) S_b Q(V_bᵀ) + Q(W_{R,b})]_b with S and the
/// optimizer-owned master copy kept high-precision.  Narrow layers are
/// one block (bit-identical to the pre-blocking packing); layers wider
/// than the packing block size split into independent per-block Eq. 3
/// splits, which is what lets init stream them from disk column block
/// by column block instead of materializing split workspaces for the
/// whole matrix.
pub struct PackedWeight {
    pub name: String,
    /// High-precision master weight — what the optimizer updates.
    pub master: Matrix,
    /// Column-partition packings, in column order.
    pub blocks: Vec<PackedBlock>,
    /// Cached effective weight (all blocks assembled) — the low-rank
    /// GEMMs are already paid by pack/refresh, so the per-step forward
    /// never recomputes them.
    eff: Matrix,
}

impl PackedWeight {
    /// Init-time Eq. 3 packing through the configured strategy, then
    /// Eq. 5 sub-distribution quantization of the factors (the same
    /// `quantize_split_parts` layout the pipeline measures).  Always a
    /// single block — the streamed multi-block path is
    /// [`TrainState::init_specs`].
    pub fn pack(name: String, w: Matrix, quant: &MetisQuantConfig, rng: &mut Rng) -> PackedWeight {
        let (blk, eff) = pack_block(&w, 0, quant, rng);
        PackedWeight {
            name,
            blocks: vec![blk],
            eff,
            master: w,
        }
    }

    /// Largest split rank k across the column blocks.
    pub fn rank(&self) -> usize {
        self.blocks.iter().map(|b| b.s.len()).max().unwrap_or(0)
    }

    /// The effective W4 weight the forward GEMMs consume (cached;
    /// refreshed by pack/refresh/repack).
    pub fn effective(&self) -> &Matrix {
        &self.eff
    }

    /// Re-fit the packing to the current master against the *frozen*
    /// init-time basis, per block: S_b ← diag(Q(U_b)ᵀ W_b Q(V_bᵀ)ᵀ)
    /// (the per-component bilinear coefficient), then the block residual
    /// W_b − Q(U_b) S_b Q(V_bᵀ) is re-quantized.  O(mnk) total — same
    /// order as the per-step Eq. 6 split, so the refresh never dominates
    /// a step.
    pub fn refresh(&mut self, fmt: Format) {
        let single = self.blocks.len() == 1;
        let (master, eff) = (&self.master, &mut self.eff);
        for blk in &mut self.blocks {
            // The col_block copy is skipped for single-block layers —
            // the historical path ran straight off the master.
            let mb_store;
            let mb = if single {
                master
            } else {
                mb_store = master.col_block(blk.c0, blk.width());
                &mb_store
            };
            // Q(U)ᵀ·W_b contracted straight from nibbles, k×width.
            let a = crate::linalg::qgemm_at_b(&blk.uq, mb);
            let mut vrow = vec![0.0f64; blk.vtq.cols];
            for (i, s) in blk.s.iter_mut().enumerate() {
                blk.vtq.row_into(i, &mut vrow);
                *s = crate::linalg::kernels::dot(a.row(i), &vrow);
            }
            let low = crate::linalg::qgemm_scaled(&blk.uq, &blk.s, &blk.vtq);
            let rq = quantize_matrix_along(fmt, &mb.sub(&low), 0);
            let eff_b = low.add(&rq);
            if single {
                *eff = eff_b;
            } else {
                eff.set_col_block(blk.c0, &eff_b);
            }
        }
    }

    /// Full Eq. 3 re-decomposition of the current master (the paper's
    /// periodic weight re-split; `TrainState` calls this every
    /// `repack_every` steps when enabled).  Single-block layers consume
    /// `rng` directly (the historical stream); multi-block layers
    /// re-pack each block from a per-block sub-stream of it.
    pub fn repack(&mut self, quant: &MetisQuantConfig, rng: &mut Rng) {
        if self.blocks.len() == 1 {
            let (blk, eff) = pack_block(&self.master, 0, quant, rng);
            self.blocks = vec![blk];
            self.eff = eff;
            return;
        }
        let base = rng.fold_in(PACK_BLOCK_DOMAIN);
        for (b, blk) in self.blocks.iter_mut().enumerate() {
            let mb = self.master.col_block(blk.c0, blk.width());
            let mut sub = base.fold_in(b as u64);
            let (packed, eff_b) = pack_block(&mb, blk.c0, quant, &mut sub);
            self.eff.set_col_block(blk.c0, &eff_b);
            *blk = packed;
        }
    }
}

/// Per-step gradient processing configuration (Eq. 6 + §3.2 + G4).
#[derive(Clone, Copy, Debug)]
pub struct GradStepConfig {
    /// Sketch rank j of the randomized split (paper rho_bwd idiom).
    pub rank: usize,
    /// Subspace (power) iterations sharpening the range finder.
    pub power_iters: usize,
    /// Apply the §3.2 adaptive spectral rescale.
    pub adaptive: bool,
    /// Block format the gradient sub-distributions are quantized in.
    pub fmt: Format,
}

impl Default for GradStepConfig {
    fn default() -> Self {
        Self {
            rank: 8,
            power_iters: 1,
            adaptive: true,
            fmt: Format::Nvfp4,
        }
    }
}

/// The per-step gradient transform: split → rescale → quantize.  One
/// value drives both the native loop and (when real bindings land) the
/// PJRT path out of `coordinator::trainer`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GradStep {
    pub cfg: GradStepConfig,
}

/// What a `GradStep` produced for one layer gradient.
pub struct GradOutcome {
    /// Effective gradient Q(P) diag(T̃) Q(Qᵀ) + Q(D_R).
    pub effective: Matrix,
    /// σ₁ of the estimated gradient spectrum.
    pub t1: f64,
    /// Mean / max §3.2 amplification σ̃ᵢ/σᵢ over the sketch spectrum.
    pub amp_mean: f64,
    pub amp_max: f64,
    /// Fraction of ‖D‖² captured by the rank-j subspace.
    pub captured: f64,
    /// Wall time of split + rescale + quantization.
    pub split_ms: f64,
}

impl GradStep {
    pub fn new(cfg: GradStepConfig) -> GradStep {
        GradStep { cfg }
    }

    /// Run one raw gradient through Eq. 6, the §3.2 rescale, and G4
    /// sub-distribution quantization.
    pub fn apply(&self, d: &Matrix, rng: &mut Rng) -> GradOutcome {
        let watch = Stopwatch::start();
        let split = gradient_split(d, self.cfg.rank, self.cfg.power_iters, self.cfg.adaptive, rng);
        let effective = quantize_grad_split(&split, self.cfg.fmt, true);
        let split_ms = watch.ms();
        let stats = rescale_stats(&split.t, &split.t_adapt);
        GradOutcome {
            effective,
            t1: stats.t1,
            amp_mean: stats.amp_mean,
            amp_max: stats.amp_max,
            captured: split.captured_energy(),
            split_ms,
        }
    }
}

/// Optimizer choice for the native loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optim {
    Sgd,
    Adam { beta1: f64, beta2: f64, eps: f64 },
}

impl Optim {
    /// Adam with the standard (0.9, 0.999, 1e-8) constants.
    pub fn adam() -> Optim {
        Optim::Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optim::Sgd => "sgd",
            Optim::Adam { .. } => "adam",
        }
    }

    pub fn from_name(s: &str) -> Option<Optim> {
        match s {
            "sgd" => Some(Optim::Sgd),
            "adam" => Some(Optim::adam()),
            _ => None,
        }
    }

    fn slot(&self, rows: usize, cols: usize) -> OptimSlot {
        match *self {
            Optim::Sgd => OptimSlot::Sgd,
            Optim::Adam { beta1, beta2, eps } => OptimSlot::Adam {
                m: Matrix::zeros(rows, cols),
                v: Matrix::zeros(rows, cols),
                t: 0,
                beta1,
                beta2,
                eps,
            },
        }
    }
}

/// Per-layer optimizer state (the m/v buffers of the trainer's flat
/// state vector, held natively per packed weight).
pub enum OptimSlot {
    Sgd,
    Adam {
        m: Matrix,
        v: Matrix,
        t: i32,
        beta1: f64,
        beta2: f64,
        eps: f64,
    },
}

impl OptimSlot {
    /// Apply one update of the effective gradient to the master weight.
    pub fn update(&mut self, master: &mut Matrix, grad: &Matrix, lr: f64) {
        match self {
            OptimSlot::Sgd => {
                for (w, g) in master.data.iter_mut().zip(&grad.data) {
                    *w -= lr * g;
                }
            }
            OptimSlot::Adam {
                m,
                v,
                t,
                beta1,
                beta2,
                eps,
            } => {
                *t += 1;
                let bc1 = 1.0 - beta1.powi(*t);
                let bc2 = 1.0 - beta2.powi(*t);
                let pairs = master
                    .data
                    .iter_mut()
                    .zip(&grad.data)
                    .zip(m.data.iter_mut().zip(v.data.iter_mut()));
                for ((w, &g), (mi, vi)) in pairs {
                    *mi = *beta1 * *mi + (1.0 - *beta1) * g;
                    *vi = *beta2 * *vi + (1.0 - *beta2) * g * g;
                    *w -= lr * (*mi / bc1) / ((*vi / bc2).sqrt() + *eps);
                }
            }
        }
    }
}

/// Per-layer per-step report entry (the σ̃ rescale stats + split timing
/// the JSONL stream carries).
#[derive(Clone, Debug)]
pub struct LayerStepStats {
    pub name: String,
    pub loss: f64,
    pub t1: f64,
    pub amp_mean: f64,
    pub amp_max: f64,
    pub captured: f64,
    pub split_ms: f64,
}

impl LayerStepStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("loss", Json::num_or_null(self.loss)),
            ("t1", Json::num_or_null(self.t1)),
            ("amp_mean", Json::num_or_null(self.amp_mean)),
            ("amp_max", Json::num_or_null(self.amp_max)),
            ("captured", Json::num_or_null(self.captured)),
            ("split_ms", Json::num_or_null(self.split_ms)),
        ])
    }
}

/// One step of the native loop: mean loss + per-layer stats, JSONL-able.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub step: usize,
    pub lr: f64,
    /// Mean per-layer loss, accumulated in layer order (thread-count
    /// invariant).
    pub loss: f64,
    pub step_ms: f64,
    pub layers: Vec<LayerStepStats>,
}

impl StepReport {
    /// Stamped JSONL row (`event: "step"`, schema v2 — v1 rows carried
    /// the `event` key but no `run_id`/`schema_version`/`seq` identity).
    pub fn to_json(&self) -> Json {
        crate::obs::stamp(
            "step",
            crate::obs::schema::STEP,
            vec![
                ("step", Json::num(self.step as f64)),
                ("loss", Json::num_or_null(self.loss)),
                ("lr", Json::num(self.lr)),
                ("ms", Json::num_or_null(self.step_ms)),
                (
                    "layers",
                    Json::Arr(self.layers.iter().map(|l| l.to_json()).collect()),
                ),
            ],
        )
    }
}

/// The engine-owned training state: packed weights + optimizer slots,
/// stepped by `step_with` with any gradient source.
pub struct TrainState {
    pub layers: Vec<PackedWeight>,
    pub opt: Vec<OptimSlot>,
    pub quant: MetisQuantConfig,
    pub grad: GradStepConfig,
    /// Full Eq. 3 re-pack period (0 = frozen init-time basis forever).
    pub repack_every: usize,
    pub seed: u64,
    pub step: usize,
}

/// One (layer, column-block) packing work unit of [`TrainState::init_specs`].
#[derive(Clone, Copy, Debug)]
struct PackUnit {
    layer: usize,
    block: usize,
    c0: usize,
    width: usize,
    single: bool,
}

/// What a packing unit sends back for reassembly: the packed factors,
/// the effective column block, and — for disk-backed sources only —
/// the master block it materialized (resident sources keep their
/// matrix in the spec and move it into the master at assembly).
struct PackUnitOut {
    packed: PackedBlock,
    master_b: Option<Matrix>,
    eff_b: Matrix,
}

/// Materialize and pack one (layer, column-block) unit from its spec.
/// Single-block resident layers are packed borrowing the spec's matrix
/// in place — no transient whole-matrix copy, matching the historical
/// resident path.
fn pack_unit(
    spec: &LayerSpec,
    u: PackUnit,
    quant: &MetisQuantConfig,
    seed: u64,
    cache: &mut ReaderCache,
) -> Result<PackUnitOut> {
    let _span = crate::obs::span_ab("pack.unit", u.layer as i64, u.block as i64);
    let wb: std::borrow::Cow<'_, Matrix> = match (&spec.source, u.single) {
        (LayerSource::Mem(w), true) => std::borrow::Cow::Borrowed(w),
        _ => std::borrow::Cow::Owned(spec.read_cols(u.c0, u.width, cache)?),
    };
    // A NaN/∞ weight would otherwise surface as a panic deep inside the
    // split's Jacobi sweep; make it a named per-layer error instead.
    if !wb.data.iter().all(|x| x.is_finite()) {
        bail!(
            "non-finite weight values in columns [{}, {}) — Eq. 3 packing \
             requires finite inputs",
            u.c0,
            u.c0 + u.width
        );
    }
    let mut rng = pack_stream(seed, u.layer, u.block, u.single);
    let (packed, eff_b) = pack_block(&wb, u.c0, quant, &mut rng);
    let master_b = match &spec.source {
        LayerSource::Npy(_) => Some(wb.into_owned()),
        LayerSource::Mem(_) => None,
    };
    Ok(PackUnitOut {
        packed,
        master_b,
        eff_b,
    })
}

impl TrainState {
    /// Init-time Eq. 3 packing of every resident layer (per-layer
    /// `fold_in`-derived streams, deterministic in `seed`) — the
    /// unblocked, single-threaded wrapper around [`Self::init_specs`];
    /// packings are bit-identical to the pre-streaming releases.
    pub fn init(
        layers: Vec<Layer>,
        quant: MetisQuantConfig,
        grad: GradStepConfig,
        optim: Optim,
        seed: u64,
    ) -> Result<TrainState> {
        let specs = layers
            .into_iter()
            .map(|l| LayerSpec::mem(l.name, l.w))
            .collect();
        Self::init_specs(specs, quant, grad, optim, seed, 0, 1)
    }

    /// Bounded-memory init-time packing: consume layer specs column
    /// block by column block through the streaming reader, sharded over
    /// the persistent [`WorkPool`].  Work units are popped largest-first
    /// for load balance and reassembled block-ordered, with per-worker
    /// reader caches so each blob is opened at most once per worker.
    /// Peak transient memory is one split workspace per worker (a few
    /// column blocks) instead of the full-matrix split workspaces of
    /// the resident path; the masters and cached effective weights stay
    /// resident, as the optimizer and forward path require.
    ///
    /// Determinism: single-block layers pack from the historical
    /// per-layer stream, blocked layers from per-(layer, block)
    /// sub-streams ([`pack_stream`]), and reassembly writes disjoint
    /// column ranges — the resulting state is bit-identical for any
    /// `threads`.
    pub fn init_specs(
        specs: Vec<LayerSpec>,
        quant: MetisQuantConfig,
        grad: GradStepConfig,
        optim: Optim,
        seed: u64,
        block_cols: usize,
        threads: usize,
    ) -> Result<TrainState> {
        if specs.is_empty() {
            bail!("trainstate: no weight matrices to pack");
        }
        let mut units: Vec<PackUnit> = Vec::new();
        let mut blocks_per_layer = vec![0usize; specs.len()];
        for (i, spec) in specs.iter().enumerate() {
            if spec.rows == 0 || spec.cols == 0 {
                bail!("trainstate: layer {} is empty", spec.name);
            }
            let blocks = column_blocks(spec.cols, block_cols);
            blocks_per_layer[i] = blocks.len();
            let single = blocks.len() == 1;
            for (b, (c0, width)) in blocks.into_iter().enumerate() {
                units.push(PackUnit {
                    layer: i,
                    block: b,
                    c0,
                    width,
                    single,
                });
            }
        }
        let n_units = units.len();
        // Largest-first queue (`pop` takes the tail → sort ascending),
        // ties broken on (layer, block) for a deterministic schedule.
        units.sort_by_key(|u| (specs[u.layer].rows * u.width, u.layer, u.block));
        let threads = threads.max(1).min(n_units);
        let queue = Mutex::new(units);
        let (tx, rx) = mpsc::channel::<(usize, usize, Result<PackedBlock>)>();

        // Reassembly targets: workers write their master/effective
        // column blocks straight into these (disjoint ranges, so
        // arrival order is irrelevant to the bits and nothing buffers
        // whole-matrix copies in the channel — only the small packed
        // factors travel back).  Resident (Mem) specs need no master
        // buffer at all: the spec's own matrix *becomes* the master
        // after the scope, so the resident path never holds a second
        // whole-matrix copy.
        let masters: Vec<Mutex<Matrix>> = specs
            .iter()
            .map(|s| match s.source {
                LayerSource::Npy(_) => Mutex::new(Matrix::zeros(s.rows, s.cols)),
                LayerSource::Mem(_) => Mutex::new(Matrix::zeros(0, 0)),
            })
            .collect();
        let effs: Vec<Mutex<Matrix>> = specs
            .iter()
            .map(|s| Mutex::new(Matrix::zeros(s.rows, s.cols)))
            .collect();

        WorkPool::global().scoped(|scope| {
            for _ in 0..threads {
                let tx = tx.clone();
                let (queue, specs, quant) = (&queue, &specs, &quant);
                let (masters, effs) = (&masters, &effs);
                scope.execute(move || {
                    let mut cache = ReaderCache::new();
                    loop {
                        let unit = queue.lock().unwrap().pop();
                        let Some(u) = unit else { break };
                        let run = || -> Result<PackedBlock> {
                            let o = pack_unit(&specs[u.layer], u, quant, seed, &mut cache)?;
                            if let Some(mb) = &o.master_b {
                                masters[u.layer].lock().unwrap().set_col_block(u.c0, mb);
                            }
                            effs[u.layer].lock().unwrap().set_col_block(u.c0, &o.eff_b);
                            Ok(o.packed)
                        };
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run))
                            .unwrap_or_else(|_| Err(anyhow!("packing worker panicked")));
                        if tx.send((u.layer, u.block, out)).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        drop(tx);

        let mut packed_blocks: Vec<Vec<(usize, PackedBlock)>> =
            (0..specs.len()).map(|_| Vec::new()).collect();
        let mut first_err: Option<anyhow::Error> = None;
        let mut n_got = 0usize;
        for (layer, block, out) in rx.iter() {
            n_got += 1;
            match out {
                Ok(p) => packed_blocks[layer].push((block, p)),
                Err(e) => {
                    if first_err.is_none() {
                        first_err =
                            Some(e.context(format!("layer {} (block {block})", specs[layer].name)));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if n_got != n_units {
            bail!("trainstate: {n_got} of {n_units} packing units reported");
        }

        let mut layers = Vec::with_capacity(specs.len());
        let mut opt = Vec::with_capacity(specs.len());
        for (i, ((spec, mut blocks), (master, eff))) in specs
            .into_iter()
            .zip(packed_blocks)
            .zip(masters.into_iter().zip(effs))
            .enumerate()
        {
            blocks.sort_by_key(|(b, _)| *b);
            if blocks.len() != blocks_per_layer[i] {
                bail!(
                    "trainstate: layer {} reassembled {} of {} blocks",
                    spec.name,
                    blocks.len(),
                    blocks_per_layer[i]
                );
            }
            opt.push(optim.slot(spec.rows, spec.cols));
            let master = match spec.source {
                // The resident spec's matrix is the master — moved, not
                // copied.
                LayerSource::Mem(w) => w,
                LayerSource::Npy(_) => master.into_inner().unwrap(),
            };
            layers.push(PackedWeight {
                name: spec.name,
                master,
                blocks: blocks.into_iter().map(|(_, p)| p).collect(),
                eff: eff.into_inner().unwrap(),
            });
        }
        Ok(TrainState {
            layers,
            opt,
            quant,
            grad,
            repack_every: 0,
            seed,
            step: 0,
        })
    }

    pub fn with_repack_every(mut self, every: usize) -> TrainState {
        self.repack_every = every;
        self
    }

    /// Run one step: `grad_fn(idx, layer, rng)` produces each layer's
    /// (loss, raw gradient wrt the effective weight); the state applies
    /// the `GradStep`, the optimizer update, and the packing refresh.
    ///
    /// Layers are sharded over the persistent [`WorkPool`] (constructed
    /// once per process, shared with `pipeline::run_specs`) pulling
    /// from a shared index queue — no per-step thread spawn/join.  Each
    /// (layer, step) computation draws from its own seed stream and the
    /// report aggregates in layer order, so the result is bit-identical
    /// for any `threads`.
    pub fn step_with<F>(&mut self, lr: f64, threads: usize, grad_fn: &F) -> StepReport
    where
        F: Fn(usize, &PackedWeight, &mut Rng) -> (f64, Matrix) + Sync,
    {
        let n = self.layers.len();
        let threads = threads.max(1).min(n);
        let watch = Stopwatch::start();
        let step = self.step;
        let _span = crate::obs::span("train.step");
        let (seed, quant, grad_cfg, repack_every) =
            (self.seed, self.quant, self.grad, self.repack_every);

        type Slot<'a> = Mutex<(&'a mut PackedWeight, &'a mut OptimSlot)>;
        let slots: Vec<Slot<'_>> = self
            .layers
            .iter_mut()
            .zip(self.opt.iter_mut())
            .map(Mutex::new)
            .collect();
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, LayerStepStats)>();
        WorkPool::global().scoped(|scope| {
            for _ in 0..threads {
                let tx = tx.clone();
                let (slots, next, grad_fn) = (&slots, &next, &grad_fn);
                scope.execute(move || loop {
                    let idx = next.fetch_add(1, Ordering::SeqCst);
                    if idx >= n {
                        break;
                    }
                    let mut slot = slots[idx].lock().unwrap();
                    let _span = crate::obs::span_ab("train.layer", idx as i64, -1);
                    let (pw, opt) = &mut *slot;
                    let pw: &mut PackedWeight = pw;
                    let opt: &mut OptimSlot = opt;
                    let mut rng = Rng::new(seed)
                        .fold_in(STEP_DOMAIN)
                        .fold_in(idx as u64)
                        .fold_in(step as u64);
                    let (loss, d) = grad_fn(idx, pw, &mut rng);
                    let out = GradStep::new(grad_cfg).apply(&d, &mut rng);
                    opt.update(&mut pw.master, &out.effective, lr);
                    if repack_every > 0 && (step + 1) % repack_every == 0 {
                        pw.repack(&quant, &mut rng);
                    } else {
                        pw.refresh(quant.fmt);
                    }
                    let stats = LayerStepStats {
                        name: pw.name.clone(),
                        loss,
                        t1: out.t1,
                        amp_mean: out.amp_mean,
                        amp_max: out.amp_max,
                        captured: out.captured,
                        split_ms: out.split_ms,
                    };
                    if tx.send((idx, stats)).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);

        let mut indexed: Vec<(usize, LayerStepStats)> = rx.iter().collect();
        indexed.sort_by_key(|(i, _)| *i);
        let layers: Vec<LayerStepStats> = indexed.into_iter().map(|(_, s)| s).collect();
        let loss = layers.iter().map(|l| l.loss).sum::<f64>() / n as f64;
        self.step += 1;
        StepReport {
            step,
            lr,
            loss,
            step_ms: watch.ms(),
            layers,
        }
    }
}

/// Configuration of the pure-Rust fallback trainer (`metis
/// train-native`): a synthetic transformer-shaped model trained with
/// the full W4A4G4 loop against planted target weights.
#[derive(Clone, Copy, Debug)]
pub struct NativeTrainConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub steps: usize,
    /// Probe-activation batch per layer per step.
    pub batch: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
    pub threads: usize,
    pub quant: MetisQuantConfig,
    pub grad: GradStepConfig,
    pub optim: Optim,
    pub repack_every: usize,
    /// Column-block size of the init-time packing (0 = one block per
    /// layer).  Narrow layers always pack as a single block, so the
    /// default only changes behavior for layers wider than it.
    pub pack_block_cols: usize,
}

impl Default for NativeTrainConfig {
    fn default() -> Self {
        Self {
            n_layers: 2,
            d_model: 64,
            steps: 50,
            batch: 32,
            lr: 0.02,
            warmup: 5,
            seed: 0,
            threads: 1,
            quant: MetisQuantConfig::default(),
            grad: GradStepConfig::default(),
            optim: Optim::Sgd,
            repack_every: 0,
            pack_block_cols: 1024,
        }
    }
}

/// Everything the native loop streams out: step reports plus (when the
/// eval harness is wired in) held-out eval reports.
pub enum NativeEvent<'a> {
    Step(&'a StepReport),
    Eval(&'a EvalReport),
}

/// Whole-run result of the native loop.
pub struct NativeRunResult {
    pub reports: Vec<StepReport>,
    /// Held-out eval rows, in emission order (empty without `--eval-every`).
    pub evals: Vec<EvalReport>,
    pub wall_ms: f64,
    pub threads: usize,
    pub diverged: bool,
}

impl NativeRunResult {
    /// Loss curve in step order.
    pub fn losses(&self) -> Vec<f64> {
        self.reports.iter().map(|r| r.loss).collect()
    }

    pub fn first_loss(&self) -> f64 {
        self.reports.first().map_or(f64::NAN, |r| r.loss)
    }

    pub fn final_loss(&self) -> f64 {
        self.reports.last().map_or(f64::NAN, |r| r.loss)
    }

    /// Write one JSON object per step.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        write_jsonl_lines(path, self.reports.iter().map(|r| r.to_json()))
    }

    /// Write one JSON object per held-out eval row — the fidelity curve
    /// that streams alongside the training curve.
    pub fn write_eval_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        write_jsonl_lines(path, self.evals.iter().map(|e| e.to_json()))
    }
}

/// Write an iterator of JSON values as JSONL, creating parent dirs.
pub(crate) fn write_jsonl_lines(
    path: impl AsRef<Path>,
    rows: impl Iterator<Item = Json>,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut out = String::new();
    for r in rows {
        out.push_str(&r.to_string());
        out.push('\n');
    }
    std::fs::write(path, out).map_err(|e| anyhow!("write {}: {e}", path.display()))
}

/// Run the native W4A4G4 loop, invoking `on_event` as each step report
/// — and, when `eval = Some((every, harness))`, each held-out eval
/// report — is produced (the CLI streams them as JSONL).
///
/// The objective is a per-layer quantized-activation regression: probe
/// activations X are drawn per (layer, step), quantized along the
/// contraction axis (A4), and pushed through the packed effective
/// weight; the target applies the same quantized activations to a
/// planted target matrix, so the measurable gap isolates the W4/G4
/// path.  Gradients are exact for this quadratic objective:
/// D = Q(X)ᵀ (Q(X)·Ŵ − Q(X)·W*) / b.
///
/// Held-out evals run after every `every`-th step over the harness's
/// split (which never overlaps the per-step probe streams), measuring
/// the task loss of the packed weights on unseen activations plus the
/// fidelity of the packing against the high-precision masters.
pub fn train_native_evented(
    cfg: &NativeTrainConfig,
    eval: Option<(usize, &EvalState)>,
    on_event: &mut dyn FnMut(&NativeEvent),
) -> Result<NativeRunResult> {
    if cfg.steps == 0 || cfg.n_layers == 0 || cfg.batch == 0 {
        bail!("train-native: steps, layers and batch must all be > 0");
    }
    if cfg.d_model < 2 {
        bail!("train-native: d-model must be >= 2");
    }
    let watch = Stopwatch::start();
    let init = synthetic_model(cfg.n_layers, cfg.d_model, cfg.seed)
        .into_iter()
        .map(|l| LayerSpec::mem(l.name, l.w))
        .collect();
    let targets: Vec<Matrix> = synthetic_model(cfg.n_layers, cfg.d_model, cfg.seed ^ TARGET_DOMAIN)
        .into_iter()
        .map(|l| l.w)
        .collect();
    let mut state = TrainState::init_specs(
        init,
        cfg.quant,
        cfg.grad,
        cfg.optim,
        cfg.seed,
        cfg.pack_block_cols,
        cfg.threads,
    )?
    .with_repack_every(cfg.repack_every);
    // Fail a mismatched eval split here, before any step burns compute.
    if let Some((_, harness)) = eval {
        harness.check_coverage(
            state
                .layers
                .iter()
                .map(|pw| (pw.name.as_str(), pw.master.rows)),
        )?;
    }
    let sched = Schedule::new(cfg.lr, cfg.warmup, cfg.steps);

    let (batch, act_fmt) = (cfg.batch, cfg.quant.fmt);
    let targets = &targets;
    let grad_fn = move |idx: usize, pw: &PackedWeight, rng: &mut Rng| {
        let x = Matrix::gaussian(rng, batch, pw.master.rows, 1.0);
        // A4 along contraction, kept in nibble form: the forward and
        // backward GEMMs contract the packed activations natively.
        let xp = crate::formats::pack_matrix_along(act_fmt, &x, 1);
        // One forward GEMM: Q(X)·(Ŵ − W*) ≡ Q(X)·Ŵ − Q(X)·W* since the
        // teacher shares the quantized activations.
        let diff = crate::linalg::qgemm_ad(&xp, &pw.effective().sub(&targets[idx]));
        let loss = 0.5 * diff.frob_norm().powi(2) / batch as f64;
        let d = crate::linalg::qgemm_at_b(&xp, &diff).scale(1.0 / batch as f64);
        (loss, d)
    };

    let mut reports = Vec::with_capacity(cfg.steps);
    let mut evals = Vec::new();
    let mut diverged = false;
    for step in 0..cfg.steps {
        let report = state.step_with(sched.lr_at(step), cfg.threads, &grad_fn);
        let bad = !report.loss.is_finite();
        on_event(&NativeEvent::Step(&report));
        reports.push(report);
        if bad {
            diverged = true;
            break;
        }
        if let Some((every, harness)) = eval {
            if every > 0 && (step + 1) % every == 0 {
                let er = harness.eval_train_state(&state, Some(targets.as_slice()), Some(step))?;
                on_event(&NativeEvent::Eval(&er));
                evals.push(er);
            }
        }
    }
    Ok(NativeRunResult {
        reports,
        evals,
        wall_ms: watch.ms(),
        threads: cfg.threads.max(1),
        diverged,
    })
}

/// [`train_native_evented`] without the eval harness, step reports only.
pub fn train_native_with(
    cfg: &NativeTrainConfig,
    on_step: &mut dyn FnMut(&StepReport),
) -> Result<NativeRunResult> {
    train_native_evented(cfg, None, &mut |ev| {
        if let NativeEvent::Step(rep) = ev {
            on_step(rep);
        }
    })
}

/// `train_native_with` without a step callback.
pub fn train_native(cfg: &NativeTrainConfig) -> Result<NativeRunResult> {
    train_native_with(cfg, &mut |_| {})
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metis::pipeline::planted_powerlaw as planted;
    use crate::metis::sampler::DecompStrategy;

    fn quant() -> MetisQuantConfig {
        MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.15,
            max_rank: 16,
        }
    }

    #[test]
    fn pack_produces_accurate_effective_weight() {
        let mut rng = Rng::new(0);
        let w = planted(&mut rng, 48, 40, 1.5);
        let pw = PackedWeight::pack("w".into(), w.clone(), &quant(), &mut rng);
        assert_eq!(pw.rank(), 6); // ceil(0.15 * 40)
        assert_eq!(pw.master, w);
        let rel = pw.effective().sub(&w).frob_norm() / w.frob_norm();
        assert!(rel > 0.0 && rel < 0.2, "nvfp4 packing error: {rel:.3}");
    }

    #[test]
    fn refresh_tracks_master_updates_through_the_frozen_basis() {
        let mut rng = Rng::new(1);
        let w = planted(&mut rng, 40, 32, 1.5);
        let mut pw = PackedWeight::pack("w".into(), w.clone(), &quant(), &mut rng);
        let s0 = pw.blocks[0].s.clone();
        // Scale the master: the diag projection is linear, so S scales
        // with it and the effective weight follows within quant error.
        pw.master = w.scale(1.5);
        pw.refresh(Format::Nvfp4);
        for (a, b) in pw.blocks[0].s.iter().zip(&s0) {
            // S entries track 1.5×(projection of w), which matches the
            // original singular values up to factor-quantization noise.
            assert!((a - 1.5 * b).abs() / (1.5 * b.abs()).max(1e-12) < 0.25, "{a} vs 1.5*{b}");
        }
        let rel = pw.effective().sub(&pw.master).frob_norm() / pw.master.frob_norm();
        assert!(rel < 0.2, "post-refresh effective error: {rel:.3}");
    }

    #[test]
    fn repack_redecomposes_the_master() {
        let mut rng = Rng::new(2);
        let w = planted(&mut rng, 32, 32, 1.5);
        let mut pw = PackedWeight::pack("w".into(), w, &quant(), &mut rng);
        // Replace the master with a fresh matrix: the frozen basis is
        // now wrong, a repack re-fits it.
        pw.master = planted(&mut rng, 32, 32, 1.5);
        pw.repack(&quant(), &mut rng);
        assert_eq!(pw.name, "w");
        let rel = pw.effective().sub(&pw.master).frob_norm() / pw.master.frob_norm();
        assert!(rel < 0.2, "post-repack effective error: {rel:.3}");
        assert_eq!(pw.rank(), 5); // ceil(0.15 * 32)
    }

    #[test]
    fn blocked_init_packs_per_column_block_and_stays_accurate() {
        // A wide layer streamed through init_specs with small packing
        // blocks: per-block Eq. 3 splits, effective weight within the
        // quantization error class of the unblocked packing, and the
        // refresh/repack paths operating per block.
        let mut rng = Rng::new(5);
        let w = planted(&mut rng, 32, 96, 1.5);
        let spec = LayerSpec::mem("wide", w.clone());
        let mut state = TrainState::init_specs(
            vec![spec],
            quant(),
            GradStepConfig::default(),
            Optim::Sgd,
            7,
            32,
            2,
        )
        .unwrap();
        let pw = &state.layers[0];
        assert_eq!(pw.blocks.len(), 3);
        assert_eq!(
            pw.blocks.iter().map(|b| (b.c0, b.width())).collect::<Vec<_>>(),
            vec![(0, 32), (32, 32), (64, 32)]
        );
        assert_eq!(pw.master, w);
        let rel = pw.effective().sub(&w).frob_norm() / w.frob_norm();
        assert!(rel > 0.0 && rel < 0.2, "blocked packing error: {rel:.3}");

        // Refresh tracks a scaled master per block.
        let pw = &mut state.layers[0];
        pw.master = w.scale(2.0);
        pw.refresh(Format::Nvfp4);
        let rel = pw.effective().sub(&pw.master).frob_norm() / pw.master.frob_norm();
        assert!(rel < 0.2, "post-refresh blocked error: {rel:.3}");
        // Repack keeps the block partition and re-fits the basis.
        let mut step_rng = Rng::new(9);
        pw.repack(&quant(), &mut step_rng);
        assert_eq!(pw.blocks.len(), 3);
        let rel = pw.effective().sub(&pw.master).frob_norm() / pw.master.frob_norm();
        assert!(rel < 0.2, "post-repack blocked error: {rel:.3}");
    }

    #[test]
    fn init_specs_is_thread_and_block_source_invariant() {
        // Same specs, 1 vs 4 packing threads → bit-identical state; and
        // single-block init_specs matches the historical init() exactly.
        let layers = || synthetic_model(1, 24, 3);
        let specs = || -> Vec<LayerSpec> {
            layers()
                .into_iter()
                .map(|l| LayerSpec::mem(l.name, l.w))
                .collect()
        };
        let g = GradStepConfig::default();
        let a = TrainState::init_specs(specs(), quant(), g, Optim::Sgd, 11, 16, 1).unwrap();
        let b = TrainState::init_specs(specs(), quant(), g, Optim::Sgd, 11, 16, 4).unwrap();
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.master, y.master);
            assert_eq!(x.effective(), y.effective());
            assert_eq!(x.blocks.len(), y.blocks.len());
            for (bx, by) in x.blocks.iter().zip(&y.blocks) {
                assert_eq!(bx.s, by.s);
                assert_eq!(bx.uq, by.uq);
                assert_eq!(bx.vtq, by.vtq);
            }
        }
        let old = TrainState::init(layers(), quant(), g, Optim::Sgd, 11).unwrap();
        let single = TrainState::init_specs(specs(), quant(), g, Optim::Sgd, 11, 0, 4).unwrap();
        for (x, y) in old.layers.iter().zip(&single.layers) {
            assert_eq!(x.effective(), y.effective());
            assert_eq!(x.blocks[0].s, y.blocks[0].s);
        }
    }

    #[test]
    fn init_specs_rejects_non_finite_layers_by_name() {
        let mut rng = Rng::new(0);
        let mut w = Matrix::gaussian(&mut rng, 12, 10, 1.0);
        w[(2, 3)] = f64::INFINITY;
        let specs = vec![
            LayerSpec::mem("ok", Matrix::gaussian(&mut rng, 12, 10, 1.0)),
            LayerSpec::mem("poisoned", w),
        ];
        let err = TrainState::init_specs(
            specs,
            quant(),
            GradStepConfig::default(),
            Optim::Sgd,
            0,
            0,
            2,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("poisoned"), "error names the layer: {msg}");
        assert!(msg.contains("non-finite"), "error names the cause: {msg}");
    }

    #[test]
    fn grad_step_outcome_is_structured_and_close() {
        let mut rng = Rng::new(3);
        let d = planted(&mut rng, 40, 32, 1.5).scale(1e-4);
        // Adaptive off: the effective gradient is D plus structured
        // quantization noise only (mirror-validated rel ≈ 0.03 for fp8).
        let gs_raw = GradStep::new(GradStepConfig {
            fmt: Format::Fp8,
            adaptive: false,
            ..GradStepConfig::default()
        });
        let out = gs_raw.apply(&d, &mut rng);
        let rel_raw = out.effective.sub(&d).frob_norm() / d.frob_norm();
        assert!(rel_raw < 0.1, "fp8 effective-gradient error: {rel_raw:.3}");
        assert!(out.t1 > 0.0);
        assert_eq!((out.amp_mean, out.amp_max), (1.0, 1.0));
        assert!(out.captured > 0.5 && out.captured <= 1.0);
        // Adaptive on: the §3.2 rescale must actually act — tail
        // directions amplified, effective gradient pushed further from
        // the raw one than quantization alone.
        let gs_ad = GradStep::new(GradStepConfig {
            fmt: Format::Fp8,
            ..GradStepConfig::default()
        });
        let out_ad = gs_ad.apply(&d, &mut rng);
        assert!(out_ad.amp_mean > 1.0 && out_ad.amp_max <= 2.0 + 1e-12);
        let rel_ad = out_ad.effective.sub(&d).frob_norm() / d.frob_norm();
        assert!(rel_ad > rel_raw, "rescale had no effect: {rel_ad:.3} vs {rel_raw:.3}");
        // Zero gradient is a no-op, not a panic.
        let z = gs_ad.apply(&Matrix::zeros(16, 12), &mut rng);
        assert!(z.effective.frob_norm() < 1e-12);
    }

    #[test]
    fn optim_slots_update_master() {
        let mut master = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let g = Matrix::from_vec(1, 2, vec![0.5, -0.5]);
        let mut sgd = OptimSlot::Sgd;
        sgd.update(&mut master, &g, 0.1);
        assert!((master.data[0] - 0.95).abs() < 1e-12);
        assert!((master.data[1] + 0.95).abs() < 1e-12);

        let mut master = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let mut adam = Optim::adam().slot(1, 2);
        adam.update(&mut master, &g, 0.1);
        // First Adam step moves by ≈ lr·sign(g) (bias-corrected).
        assert!((master.data[0] - (1.0 - 0.1)).abs() < 1e-3);
        assert!((master.data[1] - (-1.0 + 0.1)).abs() < 1e-3);
        // Second step keeps moving in the same direction.
        adam.update(&mut master, &g, 0.1);
        assert!(master.data[0] < 0.91);
    }

    #[test]
    fn step_report_serializes_finite_and_null() {
        let rep = StepReport {
            step: 3,
            lr: 0.01,
            loss: f64::NAN,
            step_ms: 1.0,
            layers: vec![LayerStepStats {
                name: "l0".into(),
                loss: 2.5,
                t1: 1.0,
                amp_mean: 1.4,
                amp_max: 1.9,
                captured: 0.8,
                split_ms: 0.2,
            }],
        };
        let j = rep.to_json();
        assert_eq!(j.req("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(j.req("loss").unwrap(), &Json::Null); // NaN → null
        let layers = j.req("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].req("name").unwrap().as_str().unwrap(), "l0");
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok(), "JSONL line must reparse");
    }

    #[test]
    fn native_training_decreases_loss() {
        let cfg = NativeTrainConfig {
            n_layers: 1,
            d_model: 24,
            steps: 15,
            batch: 16,
            lr: 0.03,
            warmup: 2,
            seed: 9,
            threads: 2,
            quant: quant(),
            grad: GradStepConfig::default(),
            optim: Optim::Sgd,
            repack_every: 0,
            pack_block_cols: 1024,
        };
        let mut seen = 0usize;
        let res = train_native_with(&cfg, &mut |_| seen += 1).unwrap();
        assert_eq!(seen, 15);
        assert!(!res.diverged);
        assert_eq!(res.reports.len(), 15);
        assert!(res.losses().iter().all(|x| x.is_finite()));
        assert!(
            res.final_loss() < 0.8 * res.first_loss(),
            "loss did not decrease: {} -> {}",
            res.first_loss(),
            res.final_loss()
        );
        // Per-layer stats are populated.
        let last = res.reports.last().unwrap();
        assert_eq!(last.layers.len(), 4);
        for l in &last.layers {
            assert!(l.t1 >= 0.0 && l.captured > 0.0 && l.split_ms >= 0.0);
            assert!(l.amp_mean >= 1.0 && l.amp_max <= 2.0 + 1e-12);
        }
    }

    #[test]
    fn adam_native_training_decreases_loss() {
        let cfg = NativeTrainConfig {
            n_layers: 1,
            d_model: 16,
            steps: 12,
            batch: 16,
            lr: 0.05,
            warmup: 2,
            seed: 4,
            threads: 1,
            quant: quant(),
            grad: GradStepConfig::default(),
            optim: Optim::adam(),
            repack_every: 0,
            pack_block_cols: 1024,
        };
        let res = train_native(&cfg).unwrap();
        assert!(!res.diverged);
        assert!(res.final_loss() < res.first_loss());
    }

    #[test]
    fn training_bit_identical_with_tracing_enabled() {
        // Spans + gated metrics on must not move a single loss bit —
        // blocked packing and a repack step so pack.unit / train.layer
        // instrumentation all fire while enabled.
        let cfg = NativeTrainConfig {
            n_layers: 1,
            d_model: 16,
            steps: 4,
            batch: 8,
            lr: 0.03,
            warmup: 1,
            seed: 5,
            threads: 2,
            quant: quant(),
            grad: GradStepConfig::default(),
            optim: Optim::Sgd,
            repack_every: 2,
            pack_block_cols: 8,
        };
        let _guard = crate::obs::span::test_lock();
        crate::obs::set_enabled(false);
        let off = train_native(&cfg).unwrap();
        crate::obs::set_enabled(true);
        let on = train_native(&cfg).unwrap();
        crate::obs::set_enabled(false);
        assert_eq!(off.losses(), on.losses());
        for (a, b) in off.reports.iter().zip(&on.reports) {
            for (x, y) in a.layers.iter().zip(&b.layers) {
                assert_eq!(x.loss, y.loss);
                assert_eq!(x.t1, y.t1);
                assert_eq!(x.captured, y.captured);
            }
        }
    }

    #[test]
    fn invalid_configs_error() {
        let mut cfg = NativeTrainConfig {
            steps: 0,
            ..NativeTrainConfig::default()
        };
        assert!(train_native(&cfg).is_err());
        cfg.steps = 1;
        cfg.d_model = 1;
        assert!(train_native(&cfg).is_err());
        let empty = TrainState::init(
            Vec::new(),
            quant(),
            GradStepConfig::default(),
            Optim::Sgd,
            0,
        );
        assert!(empty.is_err());
    }
}
