//! The Metis engine (paper §3), pure Rust — the spectral-domain
//! W4A4G4 quantization pipeline on the native hot path.
//!
//! The substrates ([`crate::linalg`], [`crate::formats`],
//! [`crate::spectral`]) provide decompositions and codecs; this
//! subsystem composes them into the paper's algorithm:
//!
//! * [`split`] — weight split W = U S Vᵀ + W_R (Eq. 3) and gradient
//!   split D = P T Qᵀ + D_R via randomized range finding (Eq. 6);
//! * [`sampler`] — interchangeable decomposition strategies
//!   (`Full | Rsvd | SparseSample | RandomProject`, §3.1), including
//!   the sparse-random-row-sampling sketch;
//! * [`quantizer`] — independent sub-distribution quantization in any
//!   [`crate::formats::Format`] with S/T kept high-precision
//!   (Eqs. 5/8–11), plus the σ-distortion metrics of Fig. 4;
//! * [`lr`] — the §3.2 adaptive spectral learning-rate rescale;
//! * [`pipeline`] — the multi-threaded driver behind `metis
//!   quantize-model` (checkpoint dir or synthetic model → per-layer
//!   JSONL reports), sharded at (layer, column-block) granularity with
//!   streaming `.npy` specs so paper-scale matrices sweep through with
//!   bounded memory;
//! * [`trainstate`] — the splits on the training hot path: init-time
//!   Eq. 3 packing into [`trainstate::PackedWeight`]s (streamed column
//!   block by column block from `LayerSpec`s, bounded-memory), per-step
//!   Eq. 6 gradient splits via [`trainstate::GradStep`], and the
//!   sharded native step loop behind `metis train-native`;
//! * [`eval`] — the held-out fidelity harness: forward-only sharded
//!   eval passes over a validation split (held-out loss/perplexity,
//!   per-layer σ-distortion of the packed weights vs their masters,
//!   quantized-vs-master logit divergence), behind `metis eval` and
//!   `train-native --eval-every`.

pub mod eval;
pub mod lr;
pub mod pipeline;
pub mod quantizer;
pub mod sampler;
pub mod split;
pub mod trainstate;

pub use eval::{EvalConfig, EvalData, EvalLayerStats, EvalReport, EvalState};
pub use lr::{adaptive_rescale, rescale_stats, RescaleStats};
pub use pipeline::{
    column_blocks, load_checkpoint_dir, run_specs, scan_checkpoint_dir, synthetic_model, Layer,
    LayerReport, LayerSource, LayerSpec, NpySlice, PipelineConfig, PipelineResult, SigmaRef,
};
pub use quantizer::{
    compare, quantize_grad_split, quantize_split, sigma_distortion, sigma_distortion_vs,
    MetisQuantConfig, QuantCompare,
};
pub use sampler::{decompose, sampled_spectrum, sparse_sample_svd, DecompStrategy};
pub use split::{gradient_split, weight_split, GradSplit, WeightSplit};
pub use trainstate::{
    train_native, train_native_evented, train_native_with, GradStep, GradStepConfig, NativeEvent,
    NativeRunResult, NativeTrainConfig, Optim, PackedBlock, PackedWeight, StepReport, TrainState,
};
