//! Decomposition strategies for the Metis splits (paper §3.1).
//!
//! The paper's point is that the spectral decomposition must be *cheap*
//! enough to sit on the training hot path.  Four interchangeable
//! strategies produce the same `SvdResult` contract (k leading
//! singular triplets, descending σ):
//!
//! * [`DecompStrategy::Full`] — exact one-sided Jacobi SVD, O(mn²);
//!   the accuracy oracle the others are benchmarked against.
//! * [`DecompStrategy::Rsvd`] — Halko-style randomized SVD with 2
//!   subspace (power) iterations, O(mnk).
//! * [`DecompStrategy::SparseSample`] — §3.1 sparse random row
//!   sampling: sample s ≪ m rows of A (scaled by √(m/s) so the sketch
//!   Gram is unbiased), SVD the small sketch for approximate right
//!   singular vectors, then lift the subspace through one refinement
//!   pass (QR of A·V_l, small SVD of QᵀA).  Cheapest start, near-RSVD
//!   accuracy on the anisotropic spectra the paper targets.
//! * [`DecompStrategy::RandomProject`] — pure Gaussian random
//!   projection (randomized range finder with zero power iterations);
//!   the §3.1 "random embedding" lower bound on cost.

use crate::linalg::{householder_qr, jacobi_svd, randomized_svd, SvdResult};
use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Extra sketch columns beyond k shared by the randomized strategies.
pub const OVERSAMPLE: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecompStrategy {
    Full,
    Rsvd,
    SparseSample,
    RandomProject,
}

impl DecompStrategy {
    /// Every strategy, in cost order (cheapest decomposition last).
    pub const ALL: [DecompStrategy; 4] = [
        DecompStrategy::Full,
        DecompStrategy::Rsvd,
        DecompStrategy::SparseSample,
        DecompStrategy::RandomProject,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DecompStrategy::Full => "full",
            DecompStrategy::Rsvd => "rsvd",
            DecompStrategy::SparseSample => "sparse_sample",
            DecompStrategy::RandomProject => "random_project",
        }
    }

    pub fn from_name(s: &str) -> Option<DecompStrategy> {
        match s {
            "full" => Some(DecompStrategy::Full),
            "rsvd" => Some(DecompStrategy::Rsvd),
            "sparse_sample" => Some(DecompStrategy::SparseSample),
            "random_project" => Some(DecompStrategy::RandomProject),
            _ => None,
        }
    }
}

/// Rank-k decomposition of `a` via the chosen strategy.  `k` is clamped
/// to the matrix rank bound; degenerate (empty) matrices return an
/// empty result rather than panicking.
pub fn decompose(a: &Matrix, k: usize, strategy: DecompStrategy, rng: &mut Rng) -> SvdResult {
    let r = a.min_dim();
    if r == 0 || k == 0 {
        return SvdResult {
            u: Matrix::zeros(a.rows, 0),
            s: Vec::new(),
            v: Matrix::zeros(a.cols, 0),
        };
    }
    let k = k.min(r);
    match strategy {
        DecompStrategy::Full => jacobi_svd(a).truncated(k),
        DecompStrategy::Rsvd => randomized_svd(a, k, OVERSAMPLE, 2, rng),
        DecompStrategy::SparseSample => sparse_sample_svd(a, k, OVERSAMPLE, rng),
        DecompStrategy::RandomProject => randomized_svd(a, k, OVERSAMPLE, 0, rng),
    }
}

/// Top-k spectrum through the §3.1 row-sampling sketch — the
/// σ-measurement reference (and matching reconstruction spectrum) for
/// layers past the full-Jacobi cap, keeping quantize→measure→report
/// O(mnk) where the exact spectrum would cost O(mn²).
pub fn sampled_spectrum(a: &Matrix, k: usize, rng: &mut Rng) -> Vec<f64> {
    decompose(a, k, DecompStrategy::SparseSample, rng).s
}

/// §3.1 sparse-random-row-sampling decomposition.
///
/// 1. Sample s = min(m, max(4l, l+8)) rows (l = k + oversample) without
///    replacement, scaled by √(m/s) so E[YᵀY] = AᵀA.
/// 2. Jacobi-SVD the small s×n sketch; its leading right singular
///    vectors V_l approximate A's row space.
/// 3. Lift the subspace: Q = qr(A·V_l), then the exact SVD of the small
///    l×n matrix QᵀA yields near-exact leading triplets of A (one
///    implicit power iteration sharpens the sampled subspace).
pub fn sparse_sample_svd(a: &Matrix, k: usize, oversample: usize, rng: &mut Rng) -> SvdResult {
    let (m, n) = (a.rows, a.cols);
    let l = (k + oversample).min(m).min(n);
    let s_rows = (4 * l).max(l + 8).min(m);

    // Uniform row sample without replacement.
    let mut idx: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut idx);
    idx.truncate(s_rows);
    let scale = (m as f64 / s_rows as f64).sqrt();
    let mut y = Matrix::zeros(s_rows, n);
    for (r, &src) in idx.iter().enumerate() {
        for c in 0..n {
            y[(r, c)] = a.at(src, c) * scale;
        }
    }

    // Approximate row space from the sketch.
    let sketch = jacobi_svd(&y);
    let l = l.min(sketch.s.len());
    let mut v_l = Matrix::zeros(n, l);
    for i in 0..l {
        for r in 0..n {
            v_l[(r, i)] = sketch.v.at(r, i);
        }
    }

    // Lift: one subspace refinement through A.
    let b = a.matmul(&v_l); // m×l
    let q = householder_qr(&b).q; // m×l, l ≤ m
    let c = q.matmul_at_b(a); // Qᵀ·A, l×n, no transpose copy
    let small = jacobi_svd(&c); // u: l×l, v: n×l
    let u_full = q.matmul(&small.u); // m×l

    let k = k.min(small.s.len());
    let mut u = Matrix::zeros(m, k);
    let mut v = Matrix::zeros(n, k);
    for i in 0..k {
        for r in 0..m {
            u[(r, i)] = u_full.at(r, i);
        }
        for r in 0..n {
            v[(r, i)] = small.v.at(r, i);
        }
    }
    SvdResult {
        u,
        s: small.s[..k].to_vec(),
        v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::singular_values;
    use crate::metis::pipeline::planted_powerlaw as planted;

    #[test]
    fn names_roundtrip() {
        for s in DecompStrategy::ALL {
            assert_eq!(DecompStrategy::from_name(s.name()), Some(s));
        }
        assert_eq!(DecompStrategy::from_name("nope"), None);
    }

    #[test]
    fn all_strategies_match_topk_sigma() {
        // The §3.1 accuracy contract on the paper's power-law spectra:
        // Full/Rsvd/SparseSample reproduce the top-k σ to < 1e-2
        // relative error; RandomProject (zero power iterations) is the
        // deliberately cheap end and only gets a loose bound.
        let mut rng = Rng::new(0);
        let a = planted(&mut rng, 96, 72, 1.5);
        let exact = singular_values(&a);
        let k = 8;
        for strat in DecompStrategy::ALL {
            let tol = match strat {
                DecompStrategy::RandomProject => 0.5,
                _ => 1e-2,
            };
            let got = decompose(&a, k, strat, &mut rng);
            assert_eq!(got.s.len(), k);
            assert_eq!((got.u.rows, got.u.cols), (96, k));
            assert_eq!((got.v.rows, got.v.cols), (72, k));
            for i in 0..k {
                let rel = (got.s[i] - exact[i]).abs() / exact[i];
                assert!(
                    rel < tol,
                    "{} σ{i}: {} vs {} (rel {rel:.2e})",
                    strat.name(),
                    got.s[i],
                    exact[i]
                );
            }
        }
    }

    #[test]
    fn sparse_sample_is_accurate_and_orthonormal() {
        let mut rng = Rng::new(1);
        let a = planted(&mut rng, 128, 80, 1.5);
        let exact = singular_values(&a);
        let got = sparse_sample_svd(&a, 10, OVERSAMPLE, &mut rng);
        for i in 0..10 {
            let rel = (got.s[i] - exact[i]).abs() / exact[i];
            assert!(rel < 1e-2, "σ{i} rel {rel:.2e}");
        }
        // Factors orthonormal (the lift runs through QR + exact SVD).
        for f in [&got.u, &got.v] {
            let g = f.transpose().matmul(f);
            for i in 0..g.rows {
                for j in 0..g.cols {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((g.at(i, j) - want).abs() < 1e-8, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sampled_spectrum_tracks_exact_head() {
        // The σ-reference contract for layers past the Jacobi cap: the
        // sampled top-k spectrum matches the exact head to the same
        // < 1e-2 class as the decomposition it wraps.
        let mut rng = Rng::new(6);
        let a = planted(&mut rng, 120, 90, 1.5);
        let exact = singular_values(&a);
        let s = sampled_spectrum(&a, 12, &mut rng);
        assert_eq!(s.len(), 12);
        for i in 1..12 {
            assert!(s[i] <= s[i - 1] + 1e-12, "descending at {i}");
        }
        for i in 0..12 {
            let rel = (s[i] - exact[i]).abs() / exact[i];
            assert!(rel < 1e-2, "σ{i} rel {rel:.2e}");
        }
    }

    #[test]
    fn low_rank_matrix_is_recovered_exactly() {
        // Rank-4 matrix: sampled subspace + lift must be exact.
        let mut rng = Rng::new(2);
        let u = householder_qr(&Matrix::gaussian(&mut rng, 60, 4, 1.0)).q;
        let v = householder_qr(&Matrix::gaussian(&mut rng, 40, 4, 1.0)).q;
        let a = u.scale_cols(&[5.0, 3.0, 2.0, 1.0]).matmul(&v.transpose());
        for strat in DecompStrategy::ALL {
            let got = decompose(&a, 4, strat, &mut rng);
            let rec = got.reconstruct(4);
            let err = rec.sub(&a).frob_norm() / a.frob_norm();
            assert!(err < 1e-8, "{}: {err:.2e}", strat.name());
        }
    }

    #[test]
    fn degenerate_inputs() {
        let mut rng = Rng::new(3);
        let a = Matrix::zeros(0, 5);
        let got = decompose(&a, 3, DecompStrategy::SparseSample, &mut rng);
        assert!(got.s.is_empty());
        let b = Matrix::gaussian(&mut rng, 6, 4, 1.0);
        let got = decompose(&b, 0, DecompStrategy::Full, &mut rng);
        assert!(got.s.is_empty());
        // k beyond rank clamps.
        let got = decompose(&b, 99, DecompStrategy::Rsvd, &mut rng);
        assert!(got.s.len() <= 4);
    }

    #[test]
    fn small_matrices_where_sampling_covers_all_rows() {
        // s_rows clamps to m: sampling degenerates to a row permutation
        // and the result must still be accurate.
        let mut rng = Rng::new(4);
        let a = planted(&mut rng, 20, 16, 1.5);
        let exact = singular_values(&a);
        let got = sparse_sample_svd(&a, 5, OVERSAMPLE, &mut rng);
        for i in 0..5 {
            let rel = (got.s[i] - exact[i]).abs() / exact[i];
            assert!(rel < 1e-6, "σ{i} rel {rel:.2e}");
        }
    }
}
