//! Sharded Metis quantization driver with a bounded-memory large-layer
//! path.
//!
//! Sweeps a whole model's parameter set — a checkpoint directory of
//! `.npy` blobs or a synthetic transformer-shaped model — through
//! quantize → measure → report, sharding work across a std::thread
//! worker pool (the same channel idiom as the trainer's prefetch
//! loader).  Two granularities share one queue:
//!
//! * **layer units** — a layer whose width fits `block_cols` is one
//!   work unit, processed exactly as the original layer-sharded driver
//!   did (same `fold_in` stream, bit-identical reports);
//! * **column-block units** — wider layers split into `⌈n/block_cols⌉`
//!   blocks of columns, so a single 4k²-class matrix fans out across
//!   the pool instead of monopolizing one worker, and (with an
//!   [`LayerSource::Npy`] spec) each worker streams only its own block
//!   from disk — peak resident payload is the block, never the blob.
//!
//! Determinism: every (layer, block) unit draws from its own
//! `fold_in`-derived stream and the per-layer reduction consumes blocks
//! in column order, so the report set is bit-identical for any thread
//! count.  Work units are popped largest-first for load balance; the
//! final report order is index-sorted either way.
//!
//! σ measurement: layers under `sigma_dim_cap` use the exact Jacobi
//! reference as before; above the cap, [`SigmaRef::Sampled`] switches
//! both sides of the comparison to the §3.1 sampled top-k spectrum so
//! quantize→measure→report stays O(mnk) — large layers report finite σ
//! columns instead of silently going NaN.
//!
//! Output: one [`LayerReport`] per layer (JSONL-serializable) with the
//! element-space error stats of both paths and the σ-spectrum
//! distortion metrics the split is designed to win.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::{householder_qr, jacobi_svd};
use crate::metis::quantizer::{
    compare, compare_split, sigma_distortion, sigma_distortion_vs, MetisQuantConfig,
};
use crate::metis::sampler::{sampled_spectrum, DecompStrategy};
use crate::metis::split::split_from_svd;
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::npy::{NpyReader, ReaderCache};
use crate::util::prng::Rng;
use crate::util::timer::Stopwatch;
use crate::util::workpool::WorkPool;

/// fold_in domains under each layer's `fold_in(index)` stream, disjoint
/// from `synthetic_model`'s plain `fold_in(i)` data streams.
///
/// Quantization stream of a single-block layer — the historical
/// unblocked stream, kept verbatim so layer-granularity sweeps stay
/// bit-identical to earlier releases.
const QUANT_DOMAIN: u64 = u64::MAX;
/// σ-measurement sampling streams (never shared with quantization, so
/// turning σ on/off cannot perturb the quantization numbers).
const SIGMA_DOMAIN: u64 = u64::MAX - 1;
/// Per-(layer, block) quantization streams of the blocked path.
const BLOCK_DOMAIN: u64 = u64::MAX - 2;

/// Sampled σ references never use fewer than this many spectrum points,
/// so the tail-half column stays meaningful at tiny split ranks.
/// Shared with the eval harness so `metis eval` σ columns are computed
/// on the same footing as the pipeline's.
pub(crate) const SIGMA_SAMPLE_MIN_K: usize = 8;

/// Column partition of a `cols`-wide layer into blocks of at most
/// `block_cols` columns: `(c0, width)` pairs in column order, one
/// full-width pair when blocking is off or unnecessary.  The single
/// source of block geometry for the pipeline, the streamed packer and
/// the eval harness, so their (layer, block) units always line up.
pub fn column_blocks(cols: usize, block_cols: usize) -> Vec<(usize, usize)> {
    if block_cols == 0 || cols <= block_cols {
        return vec![(0, cols)];
    }
    (0..cols.div_ceil(block_cols))
        .map(|b| {
            let c0 = b * block_cols;
            (c0, cols.min(c0 + block_cols) - c0)
        })
        .collect()
}

/// One named weight matrix fed to the pipeline.
pub struct Layer {
    pub name: String,
    pub w: Matrix,
}

/// Reference σ spectrum for layers whose min dim exceeds
/// `sigma_dim_cap`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigmaRef {
    /// Skip σ measurement above the cap (columns report NaN/null) — the
    /// historical behavior.
    Full,
    /// Measure via the §3.1 sampled top-k spectrum on both sides of the
    /// comparison: O(mnk), finite σ columns at any size.
    Sampled,
}

impl SigmaRef {
    pub fn name(&self) -> &'static str {
        match self {
            SigmaRef::Full => "full",
            SigmaRef::Sampled => "sampled",
        }
    }

    pub fn from_name(s: &str) -> Option<SigmaRef> {
        match s {
            "full" => Some(SigmaRef::Full),
            "sampled" => Some(SigmaRef::Sampled),
            _ => None,
        }
    }
}

/// Driver configuration on top of the per-matrix quantization config.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub quant: MetisQuantConfig,
    /// Worker threads (clamped to ≥ 1).
    pub threads: usize,
    /// Measure σ-spectrum distortion (extra decompositions per unit).
    pub measure_sigma: bool,
    /// Layers with min(m,n) above this use `sigma_ref` instead of the
    /// exact Jacobi reference.
    pub sigma_dim_cap: usize,
    /// Base seed; layer i uses the fold_in(i) stream.
    pub seed: u64,
    /// Intra-layer sharding: layers wider than this split into column
    /// blocks of at most `block_cols` columns (0 disables blocking).
    pub block_cols: usize,
    /// σ reference past `sigma_dim_cap`: sampled spectrum or skip.
    pub sigma_ref: SigmaRef,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            quant: MetisQuantConfig::default(),
            threads: 1,
            measure_sigma: true,
            sigma_dim_cap: 256,
            seed: 0,
            block_cols: 1024,
            sigma_ref: SigmaRef::Sampled,
        }
    }
}

/// A 2-D slice of an on-disk `.npy` payload (one layer, possibly a
/// member of a stacked `(L, m, n)` blob), streamed block by block.
#[derive(Clone, Debug)]
pub struct NpySlice {
    pub path: PathBuf,
    /// Flat element offset of this slice's first element within the
    /// payload (`l·m·n` for member l of a stacked blob).
    pub base_elem: usize,
}

impl NpySlice {
    /// Materialize the column block [c0, c0+width) of the rows×cols
    /// slice: one contiguous read when the block spans every column,
    /// one strided read per row otherwise.  Either way the transient
    /// footprint is the block, never the blob — and the open reader is
    /// reused through the caller's per-worker [`ReaderCache`] instead
    /// of reopening the blob once per (layer, block) unit.
    fn read_cols(
        &self,
        rows: usize,
        cols: usize,
        c0: usize,
        width: usize,
        cache: &mut ReaderCache,
    ) -> Result<Matrix> {
        let rdr = cache.reader(&self.path)?;
        let data = if c0 == 0 && width == cols {
            rdr.read_f64_at(self.base_elem, rows * cols)?
        } else {
            let mut data = Vec::with_capacity(rows * width);
            for r in 0..rows {
                data.extend(rdr.read_f64_at(self.base_elem + r * cols + c0, width)?);
            }
            data
        };
        Ok(Matrix::from_vec(rows, width, data))
    }
}

/// Where a layer's payload lives.
pub enum LayerSource {
    /// Resident matrix (synthetic models, already-loaded checkpoints).
    Mem(Matrix),
    /// Streamed from an `.npy` blob on demand, block by block.
    Npy(NpySlice),
}

/// A layer the pipeline can process without holding its payload:
/// shape + name up front, column blocks materialized per work unit.
pub struct LayerSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub source: LayerSource,
}

impl LayerSpec {
    pub fn mem(name: impl Into<String>, w: Matrix) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            rows: w.rows,
            cols: w.cols,
            source: LayerSource::Mem(w),
        }
    }

    /// Materialize the column block [c0, c0+width), reusing the
    /// worker's open reader for disk-backed sources.
    pub(crate) fn read_cols(
        &self,
        c0: usize,
        width: usize,
        cache: &mut ReaderCache,
    ) -> Result<Matrix> {
        match &self.source {
            LayerSource::Mem(w) => Ok(w.col_block(c0, width)),
            LayerSource::Npy(slice) => slice.read_cols(self.rows, self.cols, c0, width, cache),
        }
    }

    /// Materialize the whole layer (one-shot reader, no cache needed).
    pub fn read_all(&self) -> Result<Matrix> {
        self.read_cols(0, self.cols, &mut ReaderCache::new())
    }
}

/// Per-layer quantize→measure result.  For layers processed as several
/// column blocks, the error columns are exact column-partition
/// aggregates (see `reduce_blocks`), `quant_ms` sums the block costs
/// and `k` is the largest per-block split rank.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Split rank used (max across column blocks when blocked).
    pub k: usize,
    /// Wall time of split + both quantization paths for this layer.
    pub quant_ms: f64,
    pub metis_rel_err: f64,
    pub direct_rel_err: f64,
    pub metis_underflow: f64,
    pub direct_underflow: f64,
    /// Mean relative σ error (NaN when σ measurement was skipped).
    pub metis_sigma_err: f64,
    pub direct_sigma_err: f64,
    /// Mean relative σ error over the tail half of the spectrum.
    pub metis_sigma_tail: f64,
    pub direct_sigma_tail: f64,
}

impl LayerReport {
    /// Stamped JSONL row (`event: "layer_report"`, schema v2 — v1 rows
    /// lacked the `run_id`/`schema_version`/`seq` identity).
    pub fn to_json(&self) -> Json {
        crate::obs::stamp(
            "layer_report",
            crate::obs::schema::LAYER_REPORT,
            vec![
            ("name", Json::str(&self.name)),
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("k", Json::num(self.k as f64)),
            ("quant_ms", Json::num_or_null(self.quant_ms)),
            ("metis_rel_err", Json::num_or_null(self.metis_rel_err)),
            ("direct_rel_err", Json::num_or_null(self.direct_rel_err)),
            ("metis_underflow", Json::num_or_null(self.metis_underflow)),
            ("direct_underflow", Json::num_or_null(self.direct_underflow)),
            ("metis_sigma_err", Json::num_or_null(self.metis_sigma_err)),
            ("direct_sigma_err", Json::num_or_null(self.direct_sigma_err)),
            ("metis_sigma_tail", Json::num_or_null(self.metis_sigma_tail)),
            ("direct_sigma_tail", Json::num_or_null(self.direct_sigma_tail)),
        ])
    }
}

/// Whole-sweep result.
pub struct PipelineResult {
    pub reports: Vec<LayerReport>,
    pub wall_ms: f64,
    pub threads: usize,
}

impl PipelineResult {
    /// Layers processed per second of wall time.
    pub fn layers_per_sec(&self) -> f64 {
        self.reports.len() as f64 / (self.wall_ms / 1e3).max(1e-9)
    }

    /// Mean σ distortion across measured layers: (metis, direct).
    pub fn mean_sigma_err(&self) -> (f64, f64) {
        let measured: Vec<&LayerReport> = self
            .reports
            .iter()
            .filter(|r| r.metis_sigma_err.is_finite())
            .collect();
        if measured.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let n = measured.len() as f64;
        (
            measured.iter().map(|r| r.metis_sigma_err).sum::<f64>() / n,
            measured.iter().map(|r| r.direct_sigma_err).sum::<f64>() / n,
        )
    }

    /// Write one JSON object per layer.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = String::new();
        for r in &self.reports {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        std::fs::write(path, out).map_err(|e| anyhow!("write {}: {e}", path.display()))
    }
}

/// One (layer, column-block) work unit.
#[derive(Clone, Copy, Debug)]
struct Unit {
    layer: usize,
    block: usize,
    c0: usize,
    width: usize,
    /// Whole layer in one unit → use the historical unblocked stream.
    single: bool,
}

/// Raw per-(layer, block) measurement, reduced into a [`LayerReport`]
/// in block order.
#[derive(Clone, Debug)]
struct BlockOut {
    k: usize,
    quant_ms: f64,
    /// ‖W_b‖²_F and the non-zero element count — the exact weights for
    /// reassembling layer-level relative errors from block stats.
    norm2: f64,
    nonzeros: usize,
    width: usize,
    metis_rel_err: f64,
    direct_rel_err: f64,
    metis_underflow: f64,
    direct_underflow: f64,
    metis_sigma_err: f64,
    metis_sigma_tail: f64,
    direct_sigma_err: f64,
    direct_sigma_tail: f64,
}

fn process_block(
    wb: &Matrix,
    quant: MetisQuantConfig,
    measure_sigma: bool,
    sigma_dim_cap: usize,
    sigma_ref: SigmaRef,
    quant_rng: &mut Rng,
    sigma_rng: &Rng,
) -> BlockOut {
    let min_dim = wb.min_dim();
    let measure_full = measure_sigma && min_dim > 0 && min_dim <= sigma_dim_cap;
    let measure_sampled =
        measure_sigma && min_dim > sigma_dim_cap && sigma_ref == SigmaRef::Sampled;
    let watch = Stopwatch::start();
    // With the Full strategy under the cap, the σ reference and the
    // split come from the same Jacobi SVD — don't pay the dominant cost
    // twice.  The reference decomposition of every other configuration
    // stays outside quant_ms so the timing column keeps comparing
    // decompose+quantize cost only.
    let (cmp, quant_ms, sigma) = if measure_full && quant.strategy == DecompStrategy::Full {
        let full = jacobi_svd(wb);
        let k = quant.rank(min_dim);
        let cmp = compare_split(wb, &split_from_svd(wb, full.truncated(k)), quant.fmt);
        let quant_ms = watch.ms();
        let (ms, mt) = sigma_distortion(&full.s, &cmp.metis_recon);
        let (ds, dt) = sigma_distortion(&full.s, &cmp.direct_recon);
        (cmp, quant_ms, (ms, mt, ds, dt))
    } else {
        let cmp = compare(wb, &quant, quant_rng);
        let quant_ms = watch.ms();
        let sigma = if measure_full {
            let reference = jacobi_svd(wb).s;
            let (ms, mt) = sigma_distortion(&reference, &cmp.metis_recon);
            let (ds, dt) = sigma_distortion(&reference, &cmp.direct_recon);
            (ms, mt, ds, dt)
        } else if measure_sampled {
            // §3.1 sampled top-k spectra on *both* sides keep the whole
            // measurement O(mnk).  Three disjoint sub-streams of the σ
            // stream, so the draw is reproducible per (layer, block)
            // and independent of the quantization stream.
            let k_sig = quant.rank(min_dim).max(SIGMA_SAMPLE_MIN_K).min(min_dim);
            let reference = sampled_spectrum(wb, k_sig, &mut sigma_rng.fold_in(0));
            let metis_s = sampled_spectrum(&cmp.metis_recon, k_sig, &mut sigma_rng.fold_in(1));
            let direct_s = sampled_spectrum(&cmp.direct_recon, k_sig, &mut sigma_rng.fold_in(2));
            let (ms, mt) = sigma_distortion_vs(&reference, &metis_s);
            let (ds, dt) = sigma_distortion_vs(&reference, &direct_s);
            (ms, mt, ds, dt)
        } else {
            (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
        };
        (cmp, quant_ms, sigma)
    };
    BlockOut {
        k: cmp.k,
        quant_ms,
        norm2: wb.frob_norm().powi(2),
        nonzeros: wb.data.iter().filter(|&&x| x != 0.0).count(),
        width: wb.cols,
        metis_rel_err: cmp.metis.rel_frob_err,
        direct_rel_err: cmp.direct.rel_frob_err,
        metis_underflow: cmp.metis.underflow_frac,
        direct_underflow: cmp.direct.underflow_frac,
        metis_sigma_err: sigma.0,
        metis_sigma_tail: sigma.1,
        direct_sigma_err: sigma.2,
        direct_sigma_tail: sigma.3,
    }
}

/// A unit failure tagged with the phase that produced it, so the error
/// row can say *where* in read → validate → quantize the unit died.
type UnitResult = std::result::Result<BlockOut, (&'static str, anyhow::Error)>;

/// Structured per-unit failure — everything the JSONL `error` row
/// carries.  Built by the collector from the failing [`Unit`] plus the
/// phase-tagged error the worker sent back.
pub struct UnitError {
    pub layer: String,
    pub layer_index: usize,
    pub block: usize,
    pub c0: usize,
    pub width: usize,
    /// `read` | `validate` | `quantize`.
    pub phase: &'static str,
    pub message: String,
}

impl UnitError {
    /// Stamped JSONL row (`event: "error"`) naming the unit and phase.
    pub fn to_json(&self) -> Json {
        crate::obs::stamp(
            "error",
            crate::obs::schema::ERROR,
            vec![
                ("layer", Json::str(&self.layer)),
                ("layer_index", Json::num(self.layer_index as f64)),
                ("block", Json::num(self.block as f64)),
                ("c0", Json::num(self.c0 as f64)),
                ("width", Json::num(self.width as f64)),
                ("phase", Json::str(self.phase)),
                ("message", Json::str(&self.message)),
            ],
        )
    }

    /// Fold the structured row into an `anyhow` error: human-readable
    /// context line on top, machine-readable JSONL row as the root
    /// cause, so callers logging `{err:#}` emit both.
    fn into_error(self) -> anyhow::Error {
        let ctx = format!(
            "layer {} (block {}, cols [{}, {})) failed in phase {}",
            self.layer,
            self.block,
            self.c0,
            self.c0 + self.width,
            self.phase
        );
        anyhow!("{}", self.to_json()).context(ctx)
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

fn process_unit(
    spec: &LayerSpec,
    u: Unit,
    cfg: &PipelineConfig,
    cache: &mut ReaderCache,
) -> UnitResult {
    let _span = crate::obs::span_ab("pipeline.unit", u.layer as i64, u.block as i64);
    let wb = spec
        .read_cols(u.c0, u.width, cache)
        .map_err(|e| ("read", e))?;
    // Validate up front: a NaN/∞ weight used to surface as a panic deep
    // inside the Jacobi sweep (σ sort), killing the worker and aborting
    // the whole sweep.  Now it is a per-layer error with a name on it.
    if !wb.data.iter().all(|x| x.is_finite()) {
        return Err((
            "validate",
            anyhow!(
                "non-finite weight values in columns [{}, {}) — quantization \
                 and σ measurement require finite inputs",
                u.c0,
                u.c0 + u.width
            ),
        ));
    }
    let layer_stream = Rng::new(cfg.seed).fold_in(u.layer as u64);
    let mut quant_rng = if u.single {
        layer_stream.fold_in(QUANT_DOMAIN)
    } else {
        layer_stream.fold_in(BLOCK_DOMAIN).fold_in(u.block as u64)
    };
    let sigma_rng = layer_stream.fold_in(SIGMA_DOMAIN).fold_in(u.block as u64);
    // A panic here would poison the pool scope; surface it as this
    // unit's quantize-phase error instead.
    catch_unwind(AssertUnwindSafe(|| {
        process_block(
            &wb,
            cfg.quant,
            cfg.measure_sigma,
            cfg.sigma_dim_cap,
            cfg.sigma_ref,
            &mut quant_rng,
            &sigma_rng,
        )
    }))
    .map_err(|p| ("quantize", anyhow!("panic during quantize: {}", panic_message(&*p))))
}

/// Reassemble one layer's report from its column blocks, in block
/// order.  A single block passes its stats through untouched (keeping
/// unblocked sweeps bit-identical to the layer-granularity driver);
/// multi-block layers aggregate exactly:
///
/// * Frobenius errors — blocks partition the columns, so layer error²
///   is the sum of block error²: rel = √(Σ relᵦ²‖Wᵦ‖² / Σ‖Wᵦ‖²);
/// * underflow — non-zero-count-weighted mean (the stat is a fraction
///   of non-zero inputs);
/// * σ distortion — column-weighted mean of per-block distortions
///   (once the columns are partitioned each block has its own
///   spectrum; there is no layer-level spectrum to pool).
fn reduce_blocks(name: String, rows: usize, cols: usize, blocks: Vec<BlockOut>) -> LayerReport {
    if blocks.len() == 1 {
        let b = &blocks[0];
        return LayerReport {
            name,
            rows,
            cols,
            k: b.k,
            quant_ms: b.quant_ms,
            metis_rel_err: b.metis_rel_err,
            direct_rel_err: b.direct_rel_err,
            metis_underflow: b.metis_underflow,
            direct_underflow: b.direct_underflow,
            metis_sigma_err: b.metis_sigma_err,
            direct_sigma_err: b.direct_sigma_err,
            metis_sigma_tail: b.metis_sigma_tail,
            direct_sigma_tail: b.direct_sigma_tail,
        };
    }
    let norm2: f64 = blocks.iter().map(|b| b.norm2).sum();
    let nonzeros: f64 = blocks.iter().map(|b| b.nonzeros as f64).sum();
    let frob = |f: fn(&BlockOut) -> f64| {
        (blocks.iter().map(|b| f(b).powi(2) * b.norm2).sum::<f64>() / norm2.max(1e-300)).sqrt()
    };
    let under = |f: fn(&BlockOut) -> f64| {
        blocks.iter().map(|b| f(b) * b.nonzeros as f64).sum::<f64>() / nonzeros.max(1.0)
    };
    let sig = |f: fn(&BlockOut) -> f64| {
        blocks.iter().map(|b| f(b) * b.width as f64).sum::<f64>() / cols as f64
    };
    LayerReport {
        name,
        rows,
        cols,
        k: blocks.iter().map(|b| b.k).max().unwrap_or(0),
        quant_ms: blocks.iter().map(|b| b.quant_ms).sum(),
        metis_rel_err: frob(|b| b.metis_rel_err),
        direct_rel_err: frob(|b| b.direct_rel_err),
        metis_underflow: under(|b| b.metis_underflow),
        direct_underflow: under(|b| b.direct_underflow),
        metis_sigma_err: sig(|b| b.metis_sigma_err),
        direct_sigma_err: sig(|b| b.direct_sigma_err),
        metis_sigma_tail: sig(|b| b.metis_sigma_tail),
        direct_sigma_tail: sig(|b| b.direct_sigma_tail),
    }
}

/// Run the sharded sweep over layer specs — the bounded-memory
/// entrypoint.  Deterministic per (layer, block) unit (seed ⊕ layer ⊕
/// block), so the report set is bit-identical for any thread count.
pub fn run_specs(specs: Vec<LayerSpec>, cfg: &PipelineConfig) -> Result<PipelineResult> {
    if specs.is_empty() {
        bail!("pipeline: no layers to process");
    }
    let watch = Stopwatch::start();
    let n_layers = specs.len();

    let mut units: Vec<Unit> = Vec::new();
    let mut blocks_per_layer = vec![0usize; n_layers];
    for (i, spec) in specs.iter().enumerate() {
        let blocks = column_blocks(spec.cols, cfg.block_cols);
        blocks_per_layer[i] = blocks.len();
        let single = blocks.len() == 1;
        for (b, (c0, width)) in blocks.into_iter().enumerate() {
            units.push(Unit {
                layer: i,
                block: b,
                c0,
                width,
                single,
            });
        }
    }
    let n_units = units.len();
    // Largest units first for load balance — `pop()` takes the Vec
    // tail, so sort *ascending* by element count (name-sorted
    // checkpoints otherwise run their big ffn blobs last, leaving one
    // straggler worker).  Ties break on (layer, block) to keep the
    // schedule deterministic; reports are index-sorted below, so the
    // output order is unchanged either way.
    units.sort_by_key(|u| (specs[u.layer].rows * u.width, u.layer, u.block));

    // Shard (layer, block) units over the persistent process-wide pool
    // (shared with `TrainState::step_with`): `threads` drain-loop jobs
    // pull from one queue, so `--threads` still caps this sweep's
    // concurrency without re-spawning OS threads per call.  Jobs borrow
    // `specs`/`queue` directly — the scope joins them before returning.
    let threads = cfg.threads.max(1).min(n_units);
    let queue = Mutex::new(units);
    let (tx, rx) = mpsc::channel::<(Unit, UnitResult)>();
    WorkPool::global().scoped(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let (queue, specs, cfg) = (&queue, &specs, *cfg);
            scope.execute(move || {
                // One reader cache per worker drain loop: every blob a
                // worker touches is opened once, however many (layer,
                // block) units of it the worker pulls.
                let mut cache = ReaderCache::new();
                loop {
                    let unit = queue.lock().unwrap().pop();
                    match unit {
                        None => break,
                        Some(u) => {
                            let out = process_unit(&specs[u.layer], u, &cfg, &mut cache);
                            if tx.send((u, out)).is_err() {
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    drop(tx);

    let mut per_layer: Vec<Vec<(usize, BlockOut)>> = (0..n_layers).map(|_| Vec::new()).collect();
    let mut n_got = 0usize;
    let mut first_err: Option<anyhow::Error> = None;
    for (u, out) in rx.iter() {
        n_got += 1;
        match out {
            Ok(o) => per_layer[u.layer].push((u.block, o)),
            Err((phase, e)) => {
                if first_err.is_none() {
                    first_err = Some(
                        UnitError {
                            layer: specs[u.layer].name.clone(),
                            layer_index: u.layer,
                            block: u.block,
                            c0: u.c0,
                            width: u.width,
                            phase,
                            message: format!("{e:#}"),
                        }
                        .into_error(),
                    );
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if n_got != n_units {
        bail!("pipeline: {n_got} of {n_units} work units reported");
    }

    let mut reports = Vec::with_capacity(n_layers);
    for (i, mut blocks) in per_layer.into_iter().enumerate() {
        // Block-ordered reassembly: the reduction consumes blocks in
        // column order no matter which worker finished first — this is
        // what carries the bit-identity guarantee to the blocked path.
        blocks.sort_by_key(|(b, _)| *b);
        if blocks.len() != blocks_per_layer[i] {
            bail!(
                "pipeline: layer {} reassembled {} of {} blocks",
                specs[i].name,
                blocks.len(),
                blocks_per_layer[i]
            );
        }
        let spec = &specs[i];
        let rep = reduce_blocks(
            spec.name.clone(),
            spec.rows,
            spec.cols,
            blocks.into_iter().map(|(_, o)| o).collect(),
        );
        // Running max of per-layer σ distortion (NaN = skipped, ignored
        // by the gauge) — lands in the metrics.json snapshot.
        crate::obs::metrics::metrics().sigma_err_max.record(rep.metis_sigma_err);
        reports.push(rep);
    }
    Ok(PipelineResult {
        reports,
        wall_ms: watch.ms(),
        threads,
    })
}

/// Run the sharded sweep over resident layers (see [`run_specs`] for
/// the streaming variant; this wraps every layer as a memory-backed
/// spec, so wide layers still shard into column blocks).
pub fn run(layers: Vec<Layer>, cfg: &PipelineConfig) -> Result<PipelineResult> {
    run_specs(
        layers
            .into_iter()
            .map(|l| LayerSpec::mem(l.name, l.w))
            .collect(),
        cfg,
    )
}

/// Scan every weight matrix under `dir` into a streaming [`LayerSpec`]
/// (sorted by file name) without reading any payload: headers are
/// parsed and validated, payloads stay on disk until a worker pulls a
/// column block.  2-D `.npy` blobs become one spec each; 3-D `(L, m,
/// n)` blobs — the layout JAX-stacked checkpoints use for per-layer
/// parameter stacks — unstack into L specs named `<stem>.<l>`.
/// Vectors/scalars such as biases are skipped.
pub fn scan_checkpoint_dir(dir: impl AsRef<Path>) -> Result<Vec<LayerSpec>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("read checkpoint dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "npy"))
        .collect();
    paths.sort();
    let mut out = Vec::new();
    for path in paths {
        let rdr = NpyReader::open(&path).with_context(|| format!("layer {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        match rdr.shape() {
            &[rows, cols] if rows >= 2 && cols >= 2 => out.push(LayerSpec {
                name,
                rows,
                cols,
                source: LayerSource::Npy(NpySlice { path, base_elem: 0 }),
            }),
            &[stack, rows, cols] if rows >= 2 && cols >= 2 => {
                for l in 0..stack {
                    out.push(LayerSpec {
                        name: format!("{name}.{l}"),
                        rows,
                        cols,
                        source: LayerSource::Npy(NpySlice {
                            path: path.clone(),
                            base_elem: l * rows * cols,
                        }),
                    });
                }
            }
            _ => continue, // biases, scalars, degenerate dims
        }
    }
    if out.is_empty() {
        bail!(
            "no 2-D or stacked 3-D .npy weight matrices under {}",
            dir.display()
        );
    }
    Ok(out)
}

/// Load every weight matrix under `dir` as a resident layer — the
/// eager counterpart of [`scan_checkpoint_dir`] for callers that need
/// the payloads in memory (e.g. the training path).
pub fn load_checkpoint_dir(dir: impl AsRef<Path>) -> Result<Vec<Layer>> {
    scan_checkpoint_dir(dir)?
        .into_iter()
        .map(|spec| {
            let w = spec
                .read_all()
                .with_context(|| format!("layer {}", spec.name))?;
            Ok(Layer { name: spec.name, w })
        })
        .collect()
}

/// Planted anisotropic matrix with the §2.1 power-law spectrum.
pub fn planted_powerlaw(rng: &mut Rng, m: usize, n: usize, power: f64) -> Matrix {
    let r = m.min(n);
    let s: Vec<f64> = (1..=r).map(|i| 10.0 * (i as f64).powf(-power)).collect();
    let q1 = householder_qr(&Matrix::gaussian(rng, m, r, 1.0)).q;
    let q2 = householder_qr(&Matrix::gaussian(rng, n, r, 1.0)).q;
    q1.scale_cols(&s).matmul_a_bt(&q2)
}

/// Synthetic transformer-shaped parameter set (4 matrices per block:
/// QKV, attention out, FFN in, FFN out) with planted power-law spectra,
/// for artifact-free pipeline runs and benches.
pub fn synthetic_model(n_layers: usize, d_model: usize, seed: u64) -> Vec<Layer> {
    let base = Rng::new(seed);
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let shapes = [
            ("attn_qkv", d_model, 3 * d_model),
            ("attn_out", d_model, d_model),
            ("ffn_in", d_model, 4 * d_model),
            ("ffn_out", 4 * d_model, d_model),
        ];
        for (i, (suffix, rows, cols)) in shapes.iter().enumerate() {
            let mut rng = base.fold_in((layer * shapes.len() + i) as u64);
            out.push(Layer {
                name: format!("layers.{layer}.{suffix}"),
                w: planted_powerlaw(&mut rng, *rows, *cols, 1.5),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::metis::sampler::DecompStrategy;

    fn small_cfg(threads: usize) -> PipelineConfig {
        PipelineConfig {
            quant: MetisQuantConfig {
                fmt: Format::Mxfp4,
                strategy: DecompStrategy::SparseSample,
                rho: 0.1,
                max_rank: 16,
            },
            threads,
            measure_sigma: false,
            sigma_dim_cap: 64,
            seed: 7,
            block_cols: 0,
            sigma_ref: SigmaRef::Sampled,
        }
    }

    #[test]
    fn synthetic_model_shapes() {
        let layers = synthetic_model(2, 16, 0);
        assert_eq!(layers.len(), 8);
        assert_eq!(layers[0].name, "layers.0.attn_qkv");
        assert_eq!((layers[0].w.rows, layers[0].w.cols), (16, 48));
        assert_eq!((layers[3].w.rows, layers[3].w.cols), (64, 16));
        // Deterministic in the seed.
        let again = synthetic_model(2, 16, 0);
        assert_eq!(layers[5].w, again[5].w);
        let other = synthetic_model(2, 16, 1);
        assert_ne!(layers[5].w, other[5].w);
    }

    #[test]
    fn run_processes_every_layer_in_order() {
        let layers = synthetic_model(1, 16, 3);
        let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
        let res = run(layers, &small_cfg(2)).unwrap();
        assert_eq!(res.threads, 2);
        let got: Vec<String> = res.reports.iter().map(|r| r.name.clone()).collect();
        assert_eq!(got, names);
        for r in &res.reports {
            assert!(r.metis_rel_err.is_finite() && r.metis_rel_err > 0.0);
            assert!(r.direct_rel_err.is_finite() && r.direct_rel_err > 0.0);
            assert!(r.k >= 1);
        }
    }

    #[test]
    fn reports_identical_for_any_thread_count() {
        let res1 = run(synthetic_model(1, 16, 9), &small_cfg(1)).unwrap();
        let res4 = run(synthetic_model(1, 16, 9), &small_cfg(4)).unwrap();
        assert_eq!(res1.reports.len(), res4.reports.len());
        for (a, b) in res1.reports.iter().zip(&res4.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
        }

        // The same guarantee on the blocked path: with 8-column blocks
        // every layer fans out into several (layer, block) units, and
        // the block-ordered reduction must erase the scheduling.
        let mut blocked = small_cfg(1);
        blocked.block_cols = 8;
        blocked.measure_sigma = true;
        blocked.sigma_dim_cap = 4; // every 16×8 block exceeds the cap → sampled σ reference
        let blk1 = run(synthetic_model(1, 16, 9), &blocked).unwrap();
        blocked.threads = 4;
        let blk4 = run(synthetic_model(1, 16, 9), &blocked).unwrap();
        assert_eq!(blk1.reports.len(), blk4.reports.len());
        for (a, b) in blk1.reports.iter().zip(&blk4.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.k, b.k);
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
            assert_eq!(a.metis_underflow, b.metis_underflow);
            assert_eq!(a.direct_underflow, b.direct_underflow);
            assert_eq!(a.metis_sigma_err, b.metis_sigma_err);
            assert_eq!(a.direct_sigma_err, b.direct_sigma_err);
        }
    }

    #[test]
    fn blocked_path_matches_unblocked_quality_class() {
        // Column-block sharding changes the split granularity (one
        // Eq. 3 split per block), so the numbers differ from the
        // layer-granularity path — but they must stay in the same
        // quality class and remain finite.
        let unblocked = run(synthetic_model(1, 16, 13), &small_cfg(2)).unwrap();
        let mut cfg = small_cfg(2);
        cfg.block_cols = 16;
        let blocked = run(synthetic_model(1, 16, 13), &cfg).unwrap();
        for (a, b) in unblocked.reports.iter().zip(&blocked.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
            assert!(b.metis_rel_err.is_finite() && b.metis_rel_err > 0.0, "{}", b.name);
            assert!(
                b.metis_rel_err < 3.0 * a.metis_rel_err + 1e-9
                    && b.metis_rel_err > a.metis_rel_err / 3.0,
                "{}: blocked {} vs unblocked {}",
                b.name,
                b.metis_rel_err,
                a.metis_rel_err
            );
        }
        // Narrow layers (cols ≤ block_cols) stay single-unit and
        // bit-identical to the unblocked run.
        let narrow = blocked
            .reports
            .iter()
            .zip(&unblocked.reports)
            .filter(|(b, _)| b.cols <= 16);
        for (b, a) in narrow {
            assert_eq!(a.metis_rel_err, b.metis_rel_err, "{}", b.name);
        }
    }

    #[test]
    fn sampled_sigma_reference_is_finite_above_the_cap() {
        // Layers above --sigma-cap used to silently report NaN σ
        // columns; with SigmaRef::Sampled they must come back finite
        // (and still favor the Metis path on anisotropic spectra).
        let mut cfg = small_cfg(2);
        cfg.measure_sigma = true;
        cfg.sigma_dim_cap = 8; // every 16-dim layer is "large"
        cfg.quant.rho = 0.25;
        cfg.sigma_ref = SigmaRef::Sampled;
        let res = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        for r in &res.reports {
            assert!(r.metis_sigma_err.is_finite(), "{}: NaN σ", r.name);
            assert!(r.direct_sigma_err.is_finite(), "{}: NaN σ", r.name);
            assert!(r.metis_sigma_tail.is_finite() && r.direct_sigma_tail.is_finite());
            // Sanity only: at 16-dim the sampled head (k_σ = 8 is half
            // the spectrum) doesn't reliably order metis vs direct —
            // the Metis win concentrates in the tail the head misses.
            // The ordering claim is asserted at realistic dims in
            // tests/metis_integration.rs (numpy-mirror-validated:
            // worst metis/direct σ ratio 0.68 at 40-dim blocks).
            assert!(r.metis_sigma_err > 0.0 && r.metis_sigma_err < 1.0, "{}", r.name);
            assert!(r.direct_sigma_err > 0.0 && r.direct_sigma_err < 1.0, "{}", r.name);
        }
        // SigmaRef::Full above the cap keeps the historical skip.
        cfg.sigma_ref = SigmaRef::Full;
        let res = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        for r in &res.reports {
            assert!(r.metis_sigma_err.is_nan(), "{}", r.name);
        }
        // And the σ reference choice never perturbs the quantization
        // numbers (disjoint fold_in domains).
        cfg.sigma_ref = SigmaRef::Sampled;
        let on = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        cfg.measure_sigma = false;
        let off = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        for (a, b) in on.reports.iter().zip(&off.reports) {
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
        }
    }

    #[test]
    fn empty_input_is_an_error() {
        assert!(run(Vec::new(), &small_cfg(1)).is_err());
    }

    #[test]
    fn non_finite_layer_is_an_error_not_a_panic() {
        // Regression: a NaN weight used to blow up as a sort panic deep
        // in the Jacobi sweep, killing a pool worker and failing the
        // run with no layer attribution.  It must now come back as a
        // named per-layer error.
        let mut rng = Rng::new(0);
        let mut w = Matrix::gaussian(&mut rng, 12, 10, 1.0);
        w[(3, 4)] = f64::NAN;
        let layers = vec![
            Layer {
                name: "good".into(),
                w: Matrix::gaussian(&mut rng, 12, 10, 1.0),
            },
            Layer {
                name: "poisoned".into(),
                w,
            },
        ];
        let mut cfg = small_cfg(2);
        cfg.measure_sigma = true;
        let err = run(layers, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("poisoned"), "error names the layer: {msg}");
        assert!(msg.contains("non-finite"), "error names the cause: {msg}");
    }

    #[test]
    fn unit_errors_carry_block_and_phase_in_the_jsonl_row() {
        // Satellite of the observability issue: a failing unit's error
        // must embed a machine-readable JSONL `error` row naming the
        // layer, block index, column range and failing phase.
        let mut rng = Rng::new(0);
        let mut w = Matrix::gaussian(&mut rng, 12, 20, 1.0);
        w[(3, 13)] = f64::NAN; // second 8-column block: cols [8, 16)
        let layers = vec![Layer {
            name: "poisoned".into(),
            w,
        }];
        let mut cfg = small_cfg(2);
        cfg.block_cols = 8;
        let err = run(layers, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        // Human context line.
        assert!(msg.contains("layer poisoned"), "{msg}");
        assert!(msg.contains("block 1"), "{msg}");
        assert!(msg.contains("cols [8, 16)"), "{msg}");
        assert!(msg.contains("phase validate"), "{msg}");
        // Machine-readable root cause: a stamped, parseable error row.
        let row_text = &msg[msg.find("{\"event\":\"error\"").expect("embedded error row")..];
        let row = Json::parse(row_text).unwrap();
        assert_eq!(row.req("layer").unwrap().as_str().unwrap(), "poisoned");
        assert_eq!(row.req("block").unwrap().as_usize().unwrap(), 1);
        assert_eq!(row.req("c0").unwrap().as_usize().unwrap(), 8);
        assert_eq!(row.req("width").unwrap().as_usize().unwrap(), 8);
        assert_eq!(row.req("phase").unwrap().as_str().unwrap(), "validate");
        assert!(row
            .req("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("non-finite"));
        assert!(row.req("run_id").unwrap().as_str().is_ok());
        assert!(row.req("seq").unwrap().as_usize().is_ok());
    }

    #[test]
    fn reports_bit_identical_with_tracing_enabled() {
        // The observability guarantee: turning spans + gated metrics on
        // must not perturb a single reported bit.  Blocked + σ-measured
        // config so the jacobi/gemm/pipeline.unit instrumentation all
        // actually fire while enabled.
        let mut cfg = small_cfg(4);
        cfg.block_cols = 8;
        cfg.measure_sigma = true;
        let _guard = crate::obs::span::test_lock();
        crate::obs::set_enabled(false);
        let off = run(synthetic_model(1, 16, 9), &cfg).unwrap();
        crate::obs::set_enabled(true);
        let on = run(synthetic_model(1, 16, 9), &cfg).unwrap();
        crate::obs::set_enabled(false);
        assert_eq!(off.reports.len(), on.reports.len());
        for (a, b) in off.reports.iter().zip(&on.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.k, b.k);
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
            assert_eq!(a.metis_underflow, b.metis_underflow);
            assert_eq!(a.direct_underflow, b.direct_underflow);
            assert_eq!(a.metis_sigma_err, b.metis_sigma_err);
            assert_eq!(a.direct_sigma_err, b.direct_sigma_err);
            assert_eq!(a.metis_sigma_tail, b.metis_sigma_tail);
            assert_eq!(a.direct_sigma_tail, b.direct_sigma_tail);
        }
    }

    #[test]
    fn jsonl_roundtrips() {
        let res = run(synthetic_model(1, 12, 5), &small_cfg(1)).unwrap();
        let dir = std::env::temp_dir().join("metis_pipeline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.jsonl");
        res.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), res.reports.len());
        for (line, rep) in lines.iter().zip(&res.reports) {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.req("name").unwrap().as_str().unwrap(), rep.name);
            assert_eq!(j.req("rows").unwrap().as_usize().unwrap(), rep.rows);
            // σ was skipped → serialized as null, not NaN.
            assert_eq!(j.req("metis_sigma_err").unwrap(), &Json::Null);
        }
    }

    #[test]
    fn measure_sigma_reports_finite_distortion() {
        // σ measurement on (the default configuration, previously only
        // unit-tested with σ off): distortion columns are finite and
        // the Metis path wins them on anisotropic layers.
        let mut cfg = small_cfg(2);
        cfg.measure_sigma = true;
        cfg.quant.rho = 0.25; // k=4 at d_model 16 — the Fig. 5 regime
        let res = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        for r in &res.reports {
            assert!(r.metis_sigma_err.is_finite() && r.metis_sigma_err > 0.0, "{}", r.name);
            assert!(r.direct_sigma_err.is_finite() && r.direct_sigma_err > 0.0, "{}", r.name);
            assert!(r.metis_sigma_tail.is_finite() && r.direct_sigma_tail.is_finite());
            assert!(r.metis_sigma_err < r.direct_sigma_err, "{}", r.name);
        }
        let (sig_m, sig_d) = res.mean_sigma_err();
        assert!(sig_m.is_finite() && sig_d.is_finite() && sig_m < sig_d);
        // σ on must not perturb the quantization numbers themselves.
        let mut off = small_cfg(2);
        off.measure_sigma = false;
        off.quant.rho = 0.25;
        let res_off = run(synthetic_model(1, 16, 21), &off).unwrap();
        for (a, b) in res.reports.iter().zip(&res_off.reports) {
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
        }
    }

    #[test]
    fn measure_sigma_full_strategy_shares_the_reference_svd() {
        // The Full-strategy fast path (split and σ reference from one
        // Jacobi SVD) must produce the same report fields as any other
        // measured run: correct k, finite σ columns.
        let mut cfg = small_cfg(1);
        cfg.quant.strategy = DecompStrategy::Full;
        cfg.measure_sigma = true;
        cfg.quant.rho = 0.25;
        let res = run(synthetic_model(1, 16, 13), &cfg).unwrap();
        for r in &res.reports {
            assert_eq!(r.k, cfg.quant.rank(r.rows.min(r.cols)));
            assert!(r.metis_sigma_err.is_finite() && r.direct_sigma_err.is_finite());
            assert!(r.metis_sigma_err < r.direct_sigma_err, "{}", r.name);
        }
    }

    #[test]
    fn checkpoint_dir_unstacks_3d_blobs() {
        // Regression: JAX-stacked checkpoints store per-layer stacks as
        // (L, m, n) blobs; these used to be silently skipped, so whole
        // models reported "no 2-D .npy weight matrices".
        use crate::util::npy::{write_npy, NpyArray};
        let dir = std::env::temp_dir().join("metis_pipeline_ckpt3d");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(0);
        let (stack, m, n) = (3usize, 8usize, 6usize);
        let mats: Vec<Matrix> = (0..stack)
            .map(|_| Matrix::gaussian(&mut rng, m, n, 1.0))
            .collect();
        let flat: Vec<f32> = mats
            .iter()
            .flat_map(|w| w.data.iter().map(|&x| x as f32))
            .collect();
        write_npy(dir.join("stack.npy"), &NpyArray::f32(vec![stack, m, n], flat)).unwrap();
        // A 3-D stack of vectors must still be skipped.
        write_npy(
            dir.join("biases.npy"),
            &NpyArray::f32(vec![2, 1, 6], vec![0.5; 12]),
        )
        .unwrap();
        let layers = load_checkpoint_dir(&dir).unwrap();
        let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["stack.0", "stack.1", "stack.2"]);
        for (layer, want) in layers.iter().zip(&mats) {
            assert_eq!((layer.w.rows, layer.w.cols), (m, n));
            let err = layer.w.sub(want).frob_norm();
            assert!(err < 1e-6, "unstacked slice diverges: {err:.2e}");
        }
        // And the unstacked layers flow through the pipeline end-to-end.
        let res = run(layers, &small_cfg(2)).unwrap();
        assert_eq!(res.reports.len(), stack);

        // The streaming specs see the same slices: every column block
        // read off disk matches the resident copy bit-for-bit.
        let specs = scan_checkpoint_dir(&dir).unwrap();
        assert_eq!(specs.len(), stack);
        // One cache across every spec: all three stacked slices share a
        // blob, so the whole loop costs a single open.
        let mut cache = ReaderCache::new();
        for (spec, want) in specs.iter().zip(&mats) {
            assert_eq!((spec.rows, spec.cols), (m, n));
            let full = spec.read_all().unwrap();
            let err = full.sub(want).frob_norm();
            assert!(err < 1e-6, "{}: disk read diverges {err:.2e}", spec.name);
            let blk = spec.read_cols(2, 3, &mut cache).unwrap();
            assert_eq!(blk, want_block(want, 2, 3), "{}", spec.name);
        }
        assert_eq!(cache.opens(), 1, "stacked slices share one reader");
    }

    fn want_block(w: &Matrix, c0: usize, width: usize) -> Matrix {
        // f32 roundtrip through the npy file, then slice.
        let mut out = Matrix::zeros(w.rows, width);
        for r in 0..w.rows {
            for c in 0..width {
                out[(r, c)] = w.at(r, c0 + c) as f32 as f64;
            }
        }
        out
    }

    #[test]
    fn checkpoint_dir_loading_filters_non_matrices() {
        let dir = std::env::temp_dir().join("metis_pipeline_ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(0);
        Matrix::gaussian(&mut rng, 8, 6, 1.0)
            .save_npy(dir.join("w1.npy"))
            .unwrap();
        Matrix::gaussian(&mut rng, 4, 4, 1.0)
            .save_npy(dir.join("w2.npy"))
            .unwrap();
        // A bias vector (1×n) must be skipped.
        Matrix::gaussian(&mut rng, 1, 6, 1.0)
            .save_npy(dir.join("b.npy"))
            .unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let layers = load_checkpoint_dir(&dir).unwrap();
        let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["w1", "w2"]);
        assert_eq!((layers[0].w.rows, layers[0].w.cols), (8, 6));
    }
}
