//! Layer-sharded Metis quantization driver.
//!
//! Sweeps a whole model's parameter set — a checkpoint directory of
//! `.npy` blobs or a synthetic transformer-shaped model — through
//! quantize → measure → report, sharding layers across a std::thread
//! worker pool (the same channel idiom as the trainer's prefetch
//! loader).  Workers pull from a shared work queue, so heterogeneous
//! layer sizes load-balance dynamically; per-layer RNG streams are
//! derived by `fold_in(layer index)`, making reports bit-identical
//! regardless of thread count.
//!
//! Output: one [`LayerReport`] per layer (JSONL-serializable) with the
//! element-space error stats of both paths and the σ-spectrum
//! distortion metrics the split is designed to win.

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::{householder_qr, jacobi_svd};
use crate::metis::quantizer::{compare, compare_split, sigma_distortion, MetisQuantConfig};
use crate::metis::sampler::DecompStrategy;
use crate::metis::split::split_from_svd;
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::timer::Stopwatch;

/// One named weight matrix fed to the pipeline.
pub struct Layer {
    pub name: String,
    pub w: Matrix,
}

/// Driver configuration on top of the per-matrix quantization config.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub quant: MetisQuantConfig,
    /// Worker threads (clamped to ≥ 1).
    pub threads: usize,
    /// Measure σ-spectrum distortion (needs 3 extra SVDs per layer).
    pub measure_sigma: bool,
    /// Layers with min(m,n) above this skip the σ measurement.
    pub sigma_dim_cap: usize,
    /// Base seed; layer i uses the fold_in(i) stream.
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            quant: MetisQuantConfig::default(),
            threads: 1,
            measure_sigma: true,
            sigma_dim_cap: 256,
            seed: 0,
        }
    }
}

/// Per-layer quantize→measure result.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Split rank used.
    pub k: usize,
    /// Wall time of split + both quantization paths for this layer.
    pub quant_ms: f64,
    pub metis_rel_err: f64,
    pub direct_rel_err: f64,
    pub metis_underflow: f64,
    pub direct_underflow: f64,
    /// Mean relative σ error (NaN when σ measurement was skipped).
    pub metis_sigma_err: f64,
    pub direct_sigma_err: f64,
    /// Mean relative σ error over the tail half of the spectrum.
    pub metis_sigma_tail: f64,
    pub direct_sigma_tail: f64,
}

impl LayerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("k", Json::num(self.k as f64)),
            ("quant_ms", Json::num_or_null(self.quant_ms)),
            ("metis_rel_err", Json::num_or_null(self.metis_rel_err)),
            ("direct_rel_err", Json::num_or_null(self.direct_rel_err)),
            ("metis_underflow", Json::num_or_null(self.metis_underflow)),
            ("direct_underflow", Json::num_or_null(self.direct_underflow)),
            ("metis_sigma_err", Json::num_or_null(self.metis_sigma_err)),
            ("direct_sigma_err", Json::num_or_null(self.direct_sigma_err)),
            ("metis_sigma_tail", Json::num_or_null(self.metis_sigma_tail)),
            ("direct_sigma_tail", Json::num_or_null(self.direct_sigma_tail)),
        ])
    }
}

/// Whole-sweep result.
pub struct PipelineResult {
    pub reports: Vec<LayerReport>,
    pub wall_ms: f64,
    pub threads: usize,
}

impl PipelineResult {
    /// Layers processed per second of wall time.
    pub fn layers_per_sec(&self) -> f64 {
        self.reports.len() as f64 / (self.wall_ms / 1e3).max(1e-9)
    }

    /// Mean σ distortion across measured layers: (metis, direct).
    pub fn mean_sigma_err(&self) -> (f64, f64) {
        let measured: Vec<&LayerReport> = self
            .reports
            .iter()
            .filter(|r| r.metis_sigma_err.is_finite())
            .collect();
        if measured.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let n = measured.len() as f64;
        (
            measured.iter().map(|r| r.metis_sigma_err).sum::<f64>() / n,
            measured.iter().map(|r| r.direct_sigma_err).sum::<f64>() / n,
        )
    }

    /// Write one JSON object per layer.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = String::new();
        for r in &self.reports {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        std::fs::write(path, out).map_err(|e| anyhow!("write {}: {e}", path.display()))
    }
}

fn process_layer(
    layer: &Layer,
    idx: usize,
    quant: MetisQuantConfig,
    measure_sigma: bool,
    sigma_dim_cap: usize,
    seed: u64,
) -> LayerReport {
    // Per-layer stream on a domain disjoint from synthetic_model's
    // fold_in(idx) streams — the sampler's sketch must be independent
    // of the data it measures.
    let mut rng = Rng::new(seed).fold_in(idx as u64).fold_in(u64::MAX);
    let measure = measure_sigma && layer.w.min_dim() > 0 && layer.w.min_dim() <= sigma_dim_cap;
    let watch = Stopwatch::start();
    // With the Full strategy the σ reference and the split come from
    // the same Jacobi SVD — don't pay the dominant cost twice.  The
    // reference SVD of the other strategies stays outside quant_ms so
    // the timing column keeps comparing decompose+quantize cost only.
    let (cmp, reference, quant_ms) = if measure && quant.strategy == DecompStrategy::Full {
        let full = jacobi_svd(&layer.w);
        let k = quant.rank(layer.w.min_dim());
        let cmp =
            compare_split(&layer.w, &split_from_svd(&layer.w, full.truncated(k)), quant.fmt);
        (cmp, Some(full.s), watch.ms())
    } else {
        let cmp = compare(&layer.w, &quant, &mut rng);
        let quant_ms = watch.ms();
        let reference = if measure {
            Some(jacobi_svd(&layer.w).s)
        } else {
            None
        };
        (cmp, reference, quant_ms)
    };
    let (m_sig, m_tail, d_sig, d_tail) = match &reference {
        Some(reference) => {
            let (ms, mt) = sigma_distortion(reference, &cmp.metis_recon);
            let (ds, dt) = sigma_distortion(reference, &cmp.direct_recon);
            (ms, mt, ds, dt)
        }
        None => (f64::NAN, f64::NAN, f64::NAN, f64::NAN),
    };
    LayerReport {
        name: layer.name.clone(),
        rows: layer.w.rows,
        cols: layer.w.cols,
        k: cmp.k,
        quant_ms,
        metis_rel_err: cmp.metis.rel_frob_err,
        direct_rel_err: cmp.direct.rel_frob_err,
        metis_underflow: cmp.metis.underflow_frac,
        direct_underflow: cmp.direct.underflow_frac,
        metis_sigma_err: m_sig,
        direct_sigma_err: d_sig,
        metis_sigma_tail: m_tail,
        direct_sigma_tail: d_tail,
    }
}

/// Run the sharded sweep.  Deterministic per layer (seed ⊕ index), so
/// the report set is identical for any thread count.
pub fn run(layers: Vec<Layer>, cfg: &PipelineConfig) -> Result<PipelineResult> {
    if layers.is_empty() {
        bail!("pipeline: no layers to process");
    }
    let threads = cfg.threads.max(1).min(layers.len());
    let watch = Stopwatch::start();
    let n_layers = layers.len();

    let queue: Arc<Mutex<Vec<(usize, Layer)>>> =
        Arc::new(Mutex::new(layers.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, LayerReport)>();
    let mut handles = Vec::with_capacity(threads);
    for _ in 0..threads {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        let quant = cfg.quant;
        let (measure_sigma, sigma_dim_cap, seed) =
            (cfg.measure_sigma, cfg.sigma_dim_cap, cfg.seed);
        handles.push(thread::spawn(move || loop {
            let item = queue.lock().unwrap().pop();
            match item {
                None => break,
                Some((idx, layer)) => {
                    let report =
                        process_layer(&layer, idx, quant, measure_sigma, sigma_dim_cap, seed);
                    if tx.send((idx, report)).is_err() {
                        break;
                    }
                }
            }
        }));
    }
    drop(tx);

    let mut indexed: Vec<(usize, LayerReport)> = rx.iter().collect();
    for h in handles {
        h.join().map_err(|_| anyhow!("pipeline worker panicked"))?;
    }
    if indexed.len() != n_layers {
        bail!(
            "pipeline: {} of {} layers reported",
            indexed.len(),
            n_layers
        );
    }
    indexed.sort_by_key(|(i, _)| *i);
    Ok(PipelineResult {
        reports: indexed.into_iter().map(|(_, r)| r).collect(),
        wall_ms: watch.ms(),
        threads,
    })
}

/// Load every weight matrix under `dir` as a layer (sorted by file
/// name).  2-D `.npy` blobs load as one layer each; 3-D `(L, m, n)`
/// blobs — the layout JAX-stacked checkpoints use for per-layer
/// parameter stacks — unstack into L layers named `<stem>.<l>`.
/// Vectors/scalars such as biases are skipped.
pub fn load_checkpoint_dir(dir: impl AsRef<Path>) -> Result<Vec<Layer>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("read checkpoint dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "npy"))
        .collect();
    paths.sort();
    let mut out = Vec::new();
    for path in paths {
        let arr = crate::util::npy::read_npy(&path)
            .with_context(|| format!("layer {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        match arr.shape.len() {
            2 if arr.shape[0] >= 2 && arr.shape[1] >= 2 => {
                let w = Matrix::from_f32(arr.shape[0], arr.shape[1], &arr.to_f32());
                out.push(Layer { name, w });
            }
            3 if arr.shape[1] >= 2 && arr.shape[2] >= 2 => {
                let (stack, m, n) = (arr.shape[0], arr.shape[1], arr.shape[2]);
                let flat = arr.to_f32();
                for l in 0..stack {
                    out.push(Layer {
                        name: format!("{name}.{l}"),
                        w: Matrix::from_f32(m, n, &flat[l * m * n..(l + 1) * m * n]),
                    });
                }
            }
            _ => continue, // biases, scalars, degenerate dims
        }
    }
    if out.is_empty() {
        bail!(
            "no 2-D or stacked 3-D .npy weight matrices under {}",
            dir.display()
        );
    }
    Ok(out)
}

/// Planted anisotropic matrix with the §2.1 power-law spectrum.
pub fn planted_powerlaw(rng: &mut Rng, m: usize, n: usize, power: f64) -> Matrix {
    let r = m.min(n);
    let s: Vec<f64> = (1..=r).map(|i| 10.0 * (i as f64).powf(-power)).collect();
    let q1 = householder_qr(&Matrix::gaussian(rng, m, r, 1.0)).q;
    let q2 = householder_qr(&Matrix::gaussian(rng, n, r, 1.0)).q;
    q1.scale_cols(&s).matmul(&q2.transpose())
}

/// Synthetic transformer-shaped parameter set (4 matrices per block:
/// QKV, attention out, FFN in, FFN out) with planted power-law spectra,
/// for artifact-free pipeline runs and benches.
pub fn synthetic_model(n_layers: usize, d_model: usize, seed: u64) -> Vec<Layer> {
    let base = Rng::new(seed);
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let shapes = [
            ("attn_qkv", d_model, 3 * d_model),
            ("attn_out", d_model, d_model),
            ("ffn_in", d_model, 4 * d_model),
            ("ffn_out", 4 * d_model, d_model),
        ];
        for (i, (suffix, rows, cols)) in shapes.iter().enumerate() {
            let mut rng = base.fold_in((layer * shapes.len() + i) as u64);
            out.push(Layer {
                name: format!("layers.{layer}.{suffix}"),
                w: planted_powerlaw(&mut rng, *rows, *cols, 1.5),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::metis::sampler::DecompStrategy;

    fn small_cfg(threads: usize) -> PipelineConfig {
        PipelineConfig {
            quant: MetisQuantConfig {
                fmt: Format::Mxfp4,
                strategy: DecompStrategy::SparseSample,
                rho: 0.1,
                max_rank: 16,
            },
            threads,
            measure_sigma: false,
            sigma_dim_cap: 64,
            seed: 7,
        }
    }

    #[test]
    fn synthetic_model_shapes() {
        let layers = synthetic_model(2, 16, 0);
        assert_eq!(layers.len(), 8);
        assert_eq!(layers[0].name, "layers.0.attn_qkv");
        assert_eq!((layers[0].w.rows, layers[0].w.cols), (16, 48));
        assert_eq!((layers[3].w.rows, layers[3].w.cols), (64, 16));
        // Deterministic in the seed.
        let again = synthetic_model(2, 16, 0);
        assert_eq!(layers[5].w, again[5].w);
        let other = synthetic_model(2, 16, 1);
        assert_ne!(layers[5].w, other[5].w);
    }

    #[test]
    fn run_processes_every_layer_in_order() {
        let layers = synthetic_model(1, 16, 3);
        let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
        let res = run(layers, &small_cfg(2)).unwrap();
        assert_eq!(res.threads, 2);
        let got: Vec<String> = res.reports.iter().map(|r| r.name.clone()).collect();
        assert_eq!(got, names);
        for r in &res.reports {
            assert!(r.metis_rel_err.is_finite() && r.metis_rel_err > 0.0);
            assert!(r.direct_rel_err.is_finite() && r.direct_rel_err > 0.0);
            assert!(r.k >= 1);
        }
    }

    #[test]
    fn reports_identical_for_any_thread_count() {
        let res1 = run(synthetic_model(1, 16, 9), &small_cfg(1)).unwrap();
        let res4 = run(synthetic_model(1, 16, 9), &small_cfg(4)).unwrap();
        assert_eq!(res1.reports.len(), res4.reports.len());
        for (a, b) in res1.reports.iter().zip(&res4.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
        }
    }

    #[test]
    fn empty_input_is_an_error() {
        assert!(run(Vec::new(), &small_cfg(1)).is_err());
    }

    #[test]
    fn jsonl_roundtrips() {
        let res = run(synthetic_model(1, 12, 5), &small_cfg(1)).unwrap();
        let dir = std::env::temp_dir().join("metis_pipeline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.jsonl");
        res.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), res.reports.len());
        for (line, rep) in lines.iter().zip(&res.reports) {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.req("name").unwrap().as_str().unwrap(), rep.name);
            assert_eq!(j.req("rows").unwrap().as_usize().unwrap(), rep.rows);
            // σ was skipped → serialized as null, not NaN.
            assert_eq!(j.req("metis_sigma_err").unwrap(), &Json::Null);
        }
    }

    #[test]
    fn measure_sigma_reports_finite_distortion() {
        // σ measurement on (the default configuration, previously only
        // unit-tested with σ off): distortion columns are finite and
        // the Metis path wins them on anisotropic layers.
        let mut cfg = small_cfg(2);
        cfg.measure_sigma = true;
        cfg.quant.rho = 0.25; // k=4 at d_model 16 — the Fig. 5 regime
        let res = run(synthetic_model(1, 16, 21), &cfg).unwrap();
        for r in &res.reports {
            assert!(r.metis_sigma_err.is_finite() && r.metis_sigma_err > 0.0, "{}", r.name);
            assert!(r.direct_sigma_err.is_finite() && r.direct_sigma_err > 0.0, "{}", r.name);
            assert!(r.metis_sigma_tail.is_finite() && r.direct_sigma_tail.is_finite());
            assert!(r.metis_sigma_err < r.direct_sigma_err, "{}", r.name);
        }
        let (sig_m, sig_d) = res.mean_sigma_err();
        assert!(sig_m.is_finite() && sig_d.is_finite() && sig_m < sig_d);
        // σ on must not perturb the quantization numbers themselves.
        let mut off = small_cfg(2);
        off.measure_sigma = false;
        off.quant.rho = 0.25;
        let res_off = run(synthetic_model(1, 16, 21), &off).unwrap();
        for (a, b) in res.reports.iter().zip(&res_off.reports) {
            assert_eq!(a.metis_rel_err, b.metis_rel_err);
            assert_eq!(a.direct_rel_err, b.direct_rel_err);
        }
    }

    #[test]
    fn measure_sigma_full_strategy_shares_the_reference_svd() {
        // The Full-strategy fast path (split and σ reference from one
        // Jacobi SVD) must produce the same report fields as any other
        // measured run: correct k, finite σ columns.
        let mut cfg = small_cfg(1);
        cfg.quant.strategy = DecompStrategy::Full;
        cfg.measure_sigma = true;
        cfg.quant.rho = 0.25;
        let res = run(synthetic_model(1, 16, 13), &cfg).unwrap();
        for r in &res.reports {
            assert_eq!(r.k, cfg.quant.rank(r.rows.min(r.cols)));
            assert!(r.metis_sigma_err.is_finite() && r.direct_sigma_err.is_finite());
            assert!(r.metis_sigma_err < r.direct_sigma_err, "{}", r.name);
        }
    }

    #[test]
    fn checkpoint_dir_unstacks_3d_blobs() {
        // Regression: JAX-stacked checkpoints store per-layer stacks as
        // (L, m, n) blobs; these used to be silently skipped, so whole
        // models reported "no 2-D .npy weight matrices".
        use crate::util::npy::{write_npy, NpyArray};
        let dir = std::env::temp_dir().join("metis_pipeline_ckpt3d");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(0);
        let (stack, m, n) = (3usize, 8usize, 6usize);
        let mats: Vec<Matrix> = (0..stack)
            .map(|_| Matrix::gaussian(&mut rng, m, n, 1.0))
            .collect();
        let flat: Vec<f32> = mats
            .iter()
            .flat_map(|w| w.data.iter().map(|&x| x as f32))
            .collect();
        write_npy(dir.join("stack.npy"), &NpyArray::f32(vec![stack, m, n], flat)).unwrap();
        // A 3-D stack of vectors must still be skipped.
        write_npy(
            dir.join("biases.npy"),
            &NpyArray::f32(vec![2, 1, 6], vec![0.5; 12]),
        )
        .unwrap();
        let layers = load_checkpoint_dir(&dir).unwrap();
        let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["stack.0", "stack.1", "stack.2"]);
        for (layer, want) in layers.iter().zip(&mats) {
            assert_eq!((layer.w.rows, layer.w.cols), (m, n));
            let err = layer.w.sub(want).frob_norm();
            assert!(err < 1e-6, "unstacked slice diverges: {err:.2e}");
        }
        // And the unstacked layers flow through the pipeline end-to-end.
        let res = run(layers, &small_cfg(2)).unwrap();
        assert_eq!(res.reports.len(), stack);
    }

    #[test]
    fn checkpoint_dir_loading_filters_non_matrices() {
        let dir = std::env::temp_dir().join("metis_pipeline_ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(0);
        Matrix::gaussian(&mut rng, 8, 6, 1.0)
            .save_npy(dir.join("w1.npy"))
            .unwrap();
        Matrix::gaussian(&mut rng, 4, 4, 1.0)
            .save_npy(dir.join("w2.npy"))
            .unwrap();
        // A bias vector (1×n) must be skipped.
        Matrix::gaussian(&mut rng, 1, 6, 1.0)
            .save_npy(dir.join("b.npy"))
            .unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let layers = load_checkpoint_dir(&dir).unwrap();
        let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["w1", "w2"]);
        assert_eq!((layers[0].w.rows, layers[0].w.cols), (8, 6));
    }
}
