//! Element-level codecs, written to match the jnp implementations in
//! `python/compile/formats.py` operation-for-operation (clamp → floor∘log2
//! → step snap with round-ties-even → clamp).  Rounding uses
//! `round_ties_even`, matching `jnp.round` semantics.

const TINY: f32 = 1e-30;

/// FP4 E2M1 snap: grid ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}, RNE, saturating.
pub fn fp4_e2m1(x: f32) -> f32 {
    let sign = if x < 0.0 {
        -1.0
    } else if x > 0.0 {
        1.0
    } else {
        return x * 0.0; // preserves ±0 like jnp.sign(x) * 0
    };
    let ax = x.abs().min(6.0);
    let e = ax.max(TINY).log2().floor().clamp(0.0, 2.0);
    let step = (e - 1.0).exp2();
    let q = (ax / step).round_ties_even() * step;
    sign * q.min(6.0)
}

/// FP8 E4M3 (finite-only) snap: bias 7, normals 2^-6..2^8, max 448,
/// subnormal step 2^-9, RNE, saturating.
pub fn fp8_e4m3(x: f32) -> f32 {
    let sign = if x < 0.0 {
        -1.0
    } else if x > 0.0 {
        1.0
    } else {
        return x * 0.0;
    };
    let ax = x.abs().min(448.0);
    let e = ax.max(TINY).log2().floor().clamp(-6.0, 8.0);
    let step = (e - 3.0).exp2();
    let q = (ax / step).round_ties_even() * step;
    sign * q.min(448.0)
}

/// E8M0 power-of-two scale (OCP MX): 2^(floor(log2 amax) − emax_elem),
/// exponent clamped to [-127, 127]; amax ≤ 0 → 1.0.
pub fn e8m0_scale(amax: f32, emax_elem: i32) -> f32 {
    if amax <= 0.0 {
        return 1.0;
    }
    let e = (amax.max(TINY).log2().floor() - emax_elem as f32).clamp(-127.0, 127.0);
    e.exp2()
}

/// Round f32 to the bfloat16 grid (round-to-nearest-even on the top 16
/// bits, matching hardware bf16 conversion).
pub fn bf16_snap(x: f32) -> f32 {
    if !x.is_finite() {
        return x;
    }
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000;
    f32::from_bits(rounded)
}

/// Enumerate the non-negative FP4 E2M1 grid (for tests/analysis).
pub fn fp4_grid() -> [f32; 8] {
    [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_idempotent_on_grid() {
        for &g in fp4_grid().iter() {
            assert_eq!(fp4_e2m1(g), g);
            assert_eq!(fp4_e2m1(-g), -g);
        }
    }

    #[test]
    fn fp4_nearest_with_rne_ties() {
        // ties: 1.75 → 2.0 (2.0 has even mantissa), 3.5 → 4.0, 5.0 → 4.0
        assert_eq!(fp4_e2m1(1.75), 2.0);
        assert_eq!(fp4_e2m1(3.5), 4.0);
        assert_eq!(fp4_e2m1(5.0), 4.0);
        assert_eq!(fp4_e2m1(0.25), 0.0); // tie 0 vs 0.5 → 0 (even)
        assert_eq!(fp4_e2m1(0.26), 0.5);
        assert_eq!(fp4_e2m1(100.0), 6.0); // saturation
        assert_eq!(fp4_e2m1(-2.4), -2.0);
    }

    #[test]
    fn fp4_exhaustive_nearest() {
        // Sweep: result must always be a grid point within half a step
        // (except at saturation).
        let grid = fp4_grid();
        let mut x = -7.0f32;
        while x < 7.0 {
            let q = fp4_e2m1(x);
            assert!(
                grid.contains(&q.abs()),
                "fp4({x}) = {q} not on grid"
            );
            // Nearest check: no other grid point strictly closer.
            let d = (q - x).abs();
            for &g in grid.iter() {
                for sg in [g, -g] {
                    assert!(
                        (sg - x).abs() >= d - 1e-6,
                        "fp4({x}) = {q}, but {sg} closer"
                    );
                }
            }
            x += 0.013;
        }
    }

    #[test]
    fn fp8_spot_values() {
        assert_eq!(fp8_e4m3(448.0), 448.0);
        assert_eq!(fp8_e4m3(500.0), 448.0);
        assert_eq!(fp8_e4m3(2.0f32.powi(-9)), 2.0f32.powi(-9)); // min subnormal
        assert_eq!(fp8_e4m3(0.0), 0.0);
        // 1.0 + 1/16 should snap onto 3-mantissa-bit grid: step at 1.0 is 1/8.
        assert_eq!(fp8_e4m3(1.0625), 1.0); // tie 1.0 vs 1.125 → even
        assert_eq!(fp8_e4m3(1.07), 1.125);
    }

    #[test]
    fn fp8_relative_error_bound() {
        // For normal-range inputs, relative error ≤ 2^-4 (half ulp of M3).
        let mut x = 0.02f32;
        while x < 400.0 {
            let q = fp8_e4m3(x);
            let rel = (q - x).abs() / x;
            assert!(rel <= 1.0 / 16.0 + 1e-6, "x={x} q={q} rel={rel}");
            x *= 1.093;
        }
    }

    #[test]
    fn e8m0_powers_of_two() {
        let s = e8m0_scale(6.0, 2);
        assert_eq!(s, 1.0); // floor(log2 6)=2, minus 2 → 2^0
        let s = e8m0_scale(0.4, 2);
        assert!((s.log2() - s.log2().round()).abs() < 1e-9);
        assert_eq!(e8m0_scale(0.0, 2), 1.0);
    }

    #[test]
    fn bf16_matches_reference_cases() {
        assert_eq!(bf16_snap(1.0), 1.0);
        // bf16 has 7 explicit mantissa bits → step 2^-7 at 1.0.
        assert_eq!(bf16_snap(1.0078125), 1.0078125);
        // 1 + 2^-8 ties between 1.0 and 1+2^-7 → even → 1.0
        assert_eq!(bf16_snap(1.00390625), 1.0);
        let x = 3.14159265f32;
        let q = bf16_snap(x);
        assert!((q - x).abs() / x < 0.004);
        assert_eq!(bf16_snap(q), q); // idempotent
    }
}
