//! Software numeric formats: bit-exact FP4 E2M1 / FP8 E4M3 / E8M0 / BF16
//! codecs and the block-scaled quantizers (MXFP4 / NVFP4 / block-FP8).
//!
//! These mirror `python/compile/formats.py` — the pytest ↔ cargo-test
//! cross-validation runs the exported Pallas quantizer artifact through
//! the Rust runtime and compares against this implementation.

pub mod blockq;
pub mod codecs;
pub mod pack;

pub use blockq::{
    pack_matrix_along, quantize_block, quantize_block_ref, quantize_matrix_along,
    quantize_matrix_along_ref, quantize_slice_into, BlockQuantizer, QuantStats,
};
pub use codecs::{bf16_snap, e8m0_scale, fp4_e2m1, fp8_e4m3};
pub use pack::PackedQMatrix;

/// Block-scaled format descriptors matching the paper §2.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// OCP MXFP4: E2M1 elements, 32-block, power-of-two (E8M0) scale.
    Mxfp4,
    /// NVFP4: E2M1 elements, 16-block, FP8 E4M3 scale = amax/6.
    Nvfp4,
    /// Block FP8: E4M3 elements, 128-block, f32 scale = amax/448.
    Fp8,
    /// The paper's §2.3 int-style scale rule s = amax/(2^{b-1}-1) on FP4.
    PaperFp4,
}

impl Format {
    /// Every implemented block format, in presentation order — the axis
    /// the Fig. 5 property test and the Metis pipeline sweep over.
    pub const ALL: [Format; 4] = [
        Format::Mxfp4,
        Format::Nvfp4,
        Format::Fp8,
        Format::PaperFp4,
    ];

    pub fn block(&self) -> usize {
        match self {
            Format::Mxfp4 | Format::PaperFp4 => 32,
            Format::Nvfp4 => 16,
            Format::Fp8 => 128,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::Mxfp4 => "mxfp4",
            Format::Nvfp4 => "nvfp4",
            Format::Fp8 => "fp8",
            Format::PaperFp4 => "paper_fp4",
        }
    }

    /// Dense index of this format in [`Format::ALL`] order — used by
    /// per-format metric arrays (`obs::metrics::PerFormat`).
    pub fn index(&self) -> usize {
        match self {
            Format::Mxfp4 => 0,
            Format::Nvfp4 => 1,
            Format::Fp8 => 2,
            Format::PaperFp4 => 3,
        }
    }

    pub fn from_name(s: &str) -> Option<Format> {
        match s {
            "mxfp4" => Some(Format::Mxfp4),
            "nvfp4" => Some(Format::Nvfp4),
            "fp8" => Some(Format::Fp8),
            "paper_fp4" => Some(Format::PaperFp4),
            _ => None,
        }
    }

    pub fn elem_max(&self) -> f32 {
        match self {
            Format::Fp8 => 448.0,
            _ => 6.0,
        }
    }

    /// Element codec.
    pub fn elem(&self, x: f32) -> f32 {
        match self {
            Format::Fp8 => fp8_e4m3(x),
            _ => fp4_e2m1(x),
        }
    }

    /// Shared-scale rule from the block absolute max.
    pub fn scale(&self, amax: f32) -> f32 {
        if amax <= 0.0 {
            return 1.0;
        }
        match self {
            Format::Mxfp4 => e8m0_scale(amax, 2),
            Format::Nvfp4 => {
                let s = fp8_e4m3(amax / 6.0);
                if s > 0.0 {
                    s
                } else {
                    1.0
                }
            }
            Format::Fp8 => amax / 448.0,
            Format::PaperFp4 => amax / 7.0,
        }
    }
}
