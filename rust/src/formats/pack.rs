//! Packed block-quantized operands: the true 4-bit (or 8-bit) storage
//! form of a quantized matrix, as contracted natively by
//! `linalg::qgemm` (ISSUE 9; "MXFP4 on native FP4 hardware" in
//! PAPERS.md).
//!
//! Layout (documented in DESIGN.md §9):
//!
//! * A **line** is one run of elements sharing the block axis: a row
//!   when `axis == 1` (activation-style, blocks along K of X·W), a
//!   column when `axis == 0` (weight-style).  Lines are stored
//!   contiguously and byte-aligned, so line starts never split a byte.
//! * FP4 formats store two codes per byte — element `e` of a line
//!   lives in byte `e / 2`, low nibble first (`e & 1 == 0` → bits 0–3).
//!   A code is `sign << 3 | grid_index`, grid = [`FP4_GRID`].  FP8
//!   stores one E4M3 byte per element (sign, 4-bit exponent bias 7,
//!   3-bit mantissa).
//! * Per-block scales live in a separate f32 array, line-major:
//!   `scales[line * blocks_per_line + block]`.
//!
//! Decoding an element reproduces the fused quantizer's arithmetic
//! *bit for bit*: `f64::from(code_value_f32 * scale_f32)` is exactly
//! the `fmt.elem(x / s) * s` product that `quantize_slice_into` wrote,
//! so `pack(A).unpack()` equals `quantize_matrix_along(fmt, A, axis)`
//! down to the sign of every zero.  That identity is what lets the
//! packed GEMM path match the expand-then-matmul oracle exactly.

use std::sync::OnceLock;

use crate::formats::Format;
use crate::tensor::Matrix;

/// Non-negative FP4 E2M1 grid in code order: `value(code) = ±FP4_GRID[code & 7]`.
pub const FP4_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Encode an on-grid E2M1 value (an output of `fp4_e2m1`) into its
/// 4-bit code.  Panics on NaN or off-grid inputs — packing only ever
/// sees values the element codec itself produced.
pub fn fp4_code(e: f32) -> u8 {
    let sign = if e.is_sign_negative() { 8u8 } else { 0u8 };
    let ax = e.abs();
    for (i, &g) in FP4_GRID.iter().enumerate() {
        if ax == g {
            return sign | (i as u8);
        }
    }
    panic!("fp4_code: {e} is not on the E2M1 grid");
}

/// Decode a 4-bit E2M1 code.  Preserves the sign of zero (code 0x8 is
/// −0.0), matching what `fp4_e2m1` returns for negative underflow.
#[inline]
pub fn fp4_value(code: u8) -> f32 {
    let mag = FP4_GRID[usize::from(code & 7)];
    if code & 8 != 0 {
        -mag
    } else {
        mag
    }
}

/// Encode an on-grid E4M3 value (an output of `fp8_e4m3`) into its
/// 8-bit code: sign | exp(bias 7) << 3 | top-3 mantissa bits.
pub fn e4m3_code(e: f32) -> u8 {
    assert!(e.is_finite(), "e4m3_code: {e} is not a finite E4M3 value");
    let sign = if e.is_sign_negative() { 0x80u8 } else { 0u8 };
    let ax = e.abs();
    if ax == 0.0 {
        return sign;
    }
    let bits = ax.to_bits();
    let exp = i64::from((bits >> 23) & 0xFF) - 127; // unbiased f32 exponent
    if exp >= -6 {
        // Normal E4M3 range: exponent field 1..=15, top 3 mantissa bits.
        let ef = exp + 7;
        assert!(
            (1..=15).contains(&ef) && bits & 0x000F_FFFF == 0,
            "e4m3_code: {e} is not on the E4M3 grid"
        );
        let m3 = ((bits >> 20) & 0x7) as u8;
        sign | ((ef as u8) << 3) | m3
    } else {
        // Subnormal: value = m · 2⁻⁹ with m ∈ 1..=7 (exp field 0).
        let m = ax * 512.0;
        assert!(
            m.fract() == 0.0 && (1.0..=7.0).contains(&m),
            "e4m3_code: {e} is not on the E4M3 grid"
        );
        sign | (m as u8)
    }
}

/// Decode an 8-bit E4M3 code.  Exact in f32 (power-of-two exponent
/// scaling of a 3-bit mantissa); preserves −0.0 via negation.
#[inline]
pub fn e4m3_value(code: u8) -> f32 {
    let ef = (code >> 3) & 0xF;
    let m = f32::from(code & 7);
    let mag = if ef == 0 {
        m * (-9.0f32).exp2()
    } else {
        (1.0 + m / 8.0) * (f32::from(ef) - 7.0).exp2()
    };
    if code & 0x80 != 0 {
        -mag
    } else {
        mag
    }
}

/// All 256 E4M3 code values, decoded once: `e4m3_lut()[c]` is exactly
/// `e4m3_value(c as u8)`, so the table-driven FP8 decode in
/// `decode_block_run` is bit-identical to calling the codec per
/// element — the per-call `exp2` is what dominated FP8 panel decode.
fn e4m3_lut() -> &'static [f32; 256] {
    static LUT: OnceLock<[f32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (c, v) in t.iter_mut().enumerate() {
            *v = e4m3_value(c as u8);
        }
        t
    })
}

/// A block-quantized matrix in packed storage: codes two-per-byte for
/// FP4 formats (one byte per code for FP8) plus a separate per-block
/// f32 scale array.  Produced by `blockq::pack_matrix_along`; consumed
/// natively by `linalg::qgemm` without materialising the dense form.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQMatrix {
    pub fmt: Format,
    pub rows: usize,
    pub cols: usize,
    /// Block axis: 0 = scale blocks run down columns (weight-style),
    /// 1 = along rows (activation-style).
    pub axis: usize,
    pub(crate) codes: Vec<u8>,
    pub(crate) scales: Vec<f32>,
}

impl PackedQMatrix {
    /// Number of lines (rows when axis 1, columns when axis 0).
    pub fn line_count(&self) -> usize {
        if self.axis == 1 {
            self.rows
        } else {
            self.cols
        }
    }

    /// Elements per line (cols when axis 1, rows when axis 0).
    pub fn line_len(&self) -> usize {
        if self.axis == 1 {
            self.cols
        } else {
            self.rows
        }
    }

    /// Scale blocks per line.
    pub fn blocks_per_line(&self) -> usize {
        self.line_len().div_ceil(self.fmt.block())
    }

    /// Code bytes per line (lines are byte-aligned).
    pub fn code_stride(&self) -> usize {
        code_stride(self.fmt, self.line_len())
    }

    /// True packed footprint in bytes: nibble/byte codes + f32 scales.
    /// This is what the `packed_bytes` metric now reports for factors.
    pub fn packed_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len()
    }

    /// Decode elements `start .. start + out.len()` of one line into
    /// f64, applying per-block scales.  This is the panel-decode the
    /// qgemm packing routines call; the AVX2 bulk path and the scalar
    /// path produce bit-identical output (table lookup + one f32
    /// multiply + exact widening in both).
    pub fn decode_line_into(&self, line: usize, start: usize, out: &mut [f64]) {
        let llen = self.line_len();
        assert!(line < self.line_count() && start + out.len() <= llen);
        let block = self.fmt.block();
        let ls = line * self.code_stride();
        let sb = line * self.blocks_per_line();
        let mut e = start;
        let mut w = 0;
        while w < out.len() {
            let b = e / block;
            let seg_end = ((b + 1) * block).min(start + out.len());
            let s = self.scales[sb + b];
            self.decode_block_run(ls, e, s, &mut out[w..w + (seg_end - e)]);
            w += seg_end - e;
            e = seg_end;
        }
    }

    /// Decode a run of elements that all share one scale.  `e` is the
    /// element index within the line; `ls` the line's first code byte.
    fn decode_block_run(&self, ls: usize, e: usize, s: f32, out: &mut [f64]) {
        if self.fmt == Format::Fp8 {
            let lut = e4m3_lut();
            for (i, o) in out.iter_mut().enumerate() {
                *o = f64::from(lut[usize::from(self.codes[ls + e + i])] * s);
            }
            return;
        }
        let mut e = e;
        let mut out = out;
        // Leading odd element: high nibble of a shared byte.
        if e & 1 == 1 && !out.is_empty() {
            out[0] = f64::from(fp4_value(self.codes[ls + e / 2] >> 4) * s);
            e += 1;
            out = &mut out[1..];
        }
        #[cfg(target_arch = "x86_64")]
        if crate::linalg::kernels::simd_active() {
            while out.len() >= 8 {
                let byte = ls + e / 2;
                // SAFETY: simd_active() implies AVX2 was detected at
                // runtime; the slice bounds were checked by the caller
                // (8 elements = 4 code bytes, 8 output f64s).
                unsafe {
                    decode8_fp4_avx2(&self.codes[byte..byte + 4], s, out.as_mut_ptr());
                }
                e += 8;
                out = &mut out[8..];
            }
        }
        // Portable tail / fallback: byte pairs then a trailing nibble.
        let mut i = 0;
        while i + 2 <= out.len() {
            let byte = self.codes[ls + e / 2];
            out[i] = f64::from(fp4_value(byte & 0xF) * s);
            out[i + 1] = f64::from(fp4_value(byte >> 4) * s);
            e += 2;
            i += 2;
        }
        if i < out.len() {
            out[i] = f64::from(fp4_value(self.codes[ls + e / 2] & 0xF) * s);
        }
    }

    /// Decode one element (row `r`, col `c`) — strided scalar access.
    pub fn decode_at(&self, r: usize, c: usize) -> f64 {
        let (line, e) = if self.axis == 1 { (r, c) } else { (c, r) };
        let s = self.scales[line * self.blocks_per_line() + e / self.fmt.block()];
        let v = if self.fmt == Format::Fp8 {
            e4m3_value(self.codes[line * self.code_stride() + e])
        } else {
            let byte = self.codes[line * self.code_stride() + e / 2];
            fp4_value((byte >> (4 * (e & 1))) & 0xF)
        };
        f64::from(v * s)
    }

    /// Decode row `r` into `out` (length `cols`), whatever the axis.
    /// Axis-1 rows are one contiguous line; axis-0 rows gather one
    /// element from every column line.
    pub fn row_into(&self, r: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.cols);
        if self.axis == 1 {
            self.decode_line_into(r, 0, out);
        } else {
            for (c, o) in out.iter_mut().enumerate() {
                *o = self.decode_at(r, c);
            }
        }
    }

    /// Expand to a dense matrix — bit-identical to what
    /// `quantize_matrix_along(fmt, a, axis)` produced for the packed
    /// source.  This is the `qgemm_ref` oracle's first half.
    pub fn unpack(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        if self.axis == 1 {
            for r in 0..self.rows {
                self.decode_line_into(r, 0, &mut out.data[r * self.cols..(r + 1) * self.cols]);
            }
        } else {
            let mut col = vec![0.0f64; self.rows];
            for c in 0..self.cols {
                self.decode_line_into(c, 0, &mut col);
                for (r, &v) in col.iter().enumerate() {
                    out.data[r * self.cols + c] = v;
                }
            }
        }
        out
    }
}

/// Code bytes for one line of `line_len` elements in `fmt`.
pub(crate) fn code_stride(fmt: Format, line_len: usize) -> usize {
    if fmt == Format::Fp8 {
        line_len
    } else {
        line_len.div_ceil(2)
    }
}

/// Encode one already-quantized element value into its code byte slot.
#[inline]
pub(crate) fn encode_into(fmt: Format, codes: &mut [u8], e_idx: usize, val: f32) {
    if fmt == Format::Fp8 {
        codes[e_idx] = e4m3_code(val);
    } else {
        let c = fp4_code(val);
        codes[e_idx / 2] |= c << (4 * (e_idx & 1));
    }
}

/// Decode 8 FP4 codes (4 bytes, low nibble first) sharing one scale
/// into 8 f64s.  Bit-identical to the scalar path: the grid lookup,
/// the sign flip (XOR on bit 31, so −0.0 survives), the single f32
/// multiply by the scale, and the exact f32→f64 widening are the same
/// operations the scalar decoder performs.
// SAFETY: caller must guarantee AVX2 is available
// (`simd_active()`), `codes` holds at least 4 bytes, and `out` points
// at at least 8 writable f64 slots.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode8_fp4_avx2(codes: &[u8], s: f32, out: *mut f64) {
    use std::arch::x86_64::*;
    let w = i32::from_le_bytes([codes[0], codes[1], codes[2], codes[3]]);
    let v = _mm256_set1_epi32(w);
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let nib = _mm256_srlv_epi32(v, shifts);
    let idx = _mm256_and_si256(nib, _mm256_set1_epi32(7));
    // bit 3 of the nibble → bit 31: an IEEE sign mask to XOR in.
    let sign = _mm256_slli_epi32::<28>(_mm256_and_si256(nib, _mm256_set1_epi32(8)));
    let grid = _mm256_loadu_ps(FP4_GRID.as_ptr());
    let mag = _mm256_permutevar8x32_ps(grid, idx);
    let vals = _mm256_xor_ps(mag, _mm256_castsi256_ps(sign));
    let scaled = _mm256_mul_ps(vals, _mm256_set1_ps(s));
    let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(scaled));
    let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(scaled));
    _mm256_storeu_pd(out, lo);
    _mm256_storeu_pd(out.add(4), hi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::codecs::{fp4_e2m1, fp8_e4m3};

    #[test]
    fn fp4_codec_roundtrips_all_codes() {
        for code in 0u8..16 {
            let v = fp4_value(code);
            assert_eq!(fp4_code(v), code, "code {code} → {v}");
            // −0.0 must keep its sign bit through the round trip.
            if code == 8 {
                assert!(v == 0.0 && v.is_sign_negative());
            }
        }
    }

    #[test]
    fn fp4_code_matches_element_codec_bitwise() {
        let mut x = -8.0f32;
        while x < 8.0 {
            let e = fp4_e2m1(x);
            let rt = fp4_value(fp4_code(e));
            assert_eq!(e.to_bits(), rt.to_bits(), "x={x} e={e} rt={rt}");
            x += 0.0137;
        }
    }

    #[test]
    #[should_panic(expected = "not on the E2M1 grid")]
    fn fp4_code_rejects_off_grid() {
        fp4_code(0.7);
    }

    #[test]
    fn e4m3_codec_roundtrips_all_finite_codes() {
        for code in 0u8..=255 {
            if (code >> 3) & 0xF == 0xF && code & 7 == 7 {
                continue; // S.1111.111 = NaN in OCP E4M3; codec never emits it
            }
            let v = e4m3_value(code);
            assert_eq!(e4m3_code(v), code, "code {code} → {v}");
        }
    }

    #[test]
    fn e4m3_code_matches_element_codec_bitwise() {
        // Sweep normals, subnormals, saturation, and negative zero.
        let mut x = 1e-4f32;
        while x < 600.0 {
            for e in [fp8_e4m3(x), fp8_e4m3(-x)] {
                let rt = e4m3_value(e4m3_code(e));
                assert_eq!(e.to_bits(), rt.to_bits(), "x={x} e={e}");
            }
            x *= 1.177;
        }
        let nz = fp8_e4m3(-1e-10);
        assert!(nz.is_sign_negative() && nz == 0.0);
        assert_eq!(e4m3_value(e4m3_code(nz)).to_bits(), nz.to_bits());
    }

    #[test]
    fn e4m3_lut_matches_codec_bitwise() {
        // The FP8 decode hot path reads the table instead of calling
        // the codec; every slot must hold the codec's exact bits
        // (including -0.0 at 0x80).
        for (c, &v) in e4m3_lut().iter().enumerate() {
            assert_eq!(v.to_bits(), e4m3_value(c as u8).to_bits(), "code {c}");
        }
    }

    #[test]
    fn packed_bytes_counts_quarter_precision() {
        use crate::formats::blockq::pack_matrix_along;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(11);
        let a = Matrix::gaussian(&mut rng, 64, 64, 1.0);
        let p = pack_matrix_along(Format::Mxfp4, &a, 0);
        // 64×64 fp4 codes = 2048 bytes + 64·2 block scales · 4 bytes.
        assert_eq!(p.packed_bytes(), 64 * 64 / 2 + 64 * 2 * 4);
        let dense_bytes = 8 * a.data.len();
        assert!(p.packed_bytes() * 4 < dense_bytes);
    }

    #[test]
    fn decode_line_handles_unaligned_starts() {
        use crate::formats::blockq::pack_matrix_along;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(12);
        for fmt in Format::ALL {
            let a = Matrix::gaussian(&mut rng, 3, 77, 1.5);
            let p = pack_matrix_along(fmt, &a, 1);
            let full = p.unpack();
            for start in [0usize, 1, 2, 15, 16, 17, 33, 76] {
                for len in [0usize, 1, 2, 7, 8, 9, 31, 77 - start] {
                    if start + len > 77 {
                        continue;
                    }
                    let mut out = vec![0.0f64; len];
                    p.decode_line_into(1, start, &mut out);
                    for (i, &v) in out.iter().enumerate() {
                        assert_eq!(
                            v.to_bits(),
                            full.data[77 + start + i].to_bits(),
                            "{} start {start} len {len} i {i}",
                            fmt.name()
                        );
                    }
                }
            }
        }
    }
}
