//! Block-wise fake quantization + error statistics (paper §2.3).
//!
//! A `BlockQuantizer` applies a `Format` along a chosen axis of a
//! `Matrix`, one shared scale per contiguous block — exactly the layout
//! of `quantize_blockwise` in python/compile/formats.py.  `QuantStats`
//! collects the bias measurements of Fig. 4: reconstruction error,
//! small-value clipping (underflow) rate, and per-magnitude-decile error.

use crate::formats::pack::{self, PackedQMatrix};
use crate::formats::Format;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct BlockQuantizer {
    pub fmt: Format,
}

impl BlockQuantizer {
    pub fn new(fmt: Format) -> Self {
        Self { fmt }
    }

    /// Quantize a 1-D block in place semantics (returns new vec).  The
    /// pre-kernel per-block path, kept as the reference oracle the
    /// fused [`quantize_slice_into`] is pinned against.
    pub fn quantize_block_vec(&self, xs: &[f32]) -> Vec<f32> {
        let amax = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let s = self.fmt.scale(amax);
        xs.iter().map(|&x| self.fmt.elem(x / s) * s).collect()
    }
}

/// Largest block width across formats — the stack-buffer bound of the
/// strided axis-0 path.  Public so the `every_format_fits_max_block`
/// guard test (and any future format addition) can see the contract.
pub const MAX_BLOCK: usize = 128;

/// Fused blockwise quantization: one walk over `xs` finding each
/// block's scale and writing the clamped/cast values straight into the
/// caller-provided `out` — no per-block allocation (the pre-kernel path
/// collected a fresh `Vec` per 16/32-element block).  Bit-identical to
/// composing [`BlockQuantizer::quantize_block_vec`] per chunk.
pub fn quantize_slice_into(fmt: Format, xs: &[f32], out: &mut [f32]) {
    assert_eq!(xs.len(), out.len(), "quantize_slice_into length mismatch");
    let block = fmt.block();
    // Per-element clip/underflow tallies are a second read-only walk,
    // gated so disabled runs keep the historical single-pass loop.
    let observe = crate::obs::enabled();
    let (mut underflow, mut clip) = (0u64, 0u64);
    for (xc, oc) in xs.chunks(block).zip(out.chunks_mut(block)) {
        let mut amax = 0.0f32;
        for &x in xc {
            amax = amax.max(x.abs());
        }
        let s = fmt.scale(amax);
        for (&x, o) in xc.iter().zip(oc.iter_mut()) {
            *o = fmt.elem(x / s) * s;
        }
        if observe {
            let lim = s * fmt.elem_max();
            for (&x, &q) in xc.iter().zip(oc.iter()) {
                underflow += u64::from(x != 0.0 && q == 0.0);
                clip += u64::from(x.abs() > lim);
            }
        }
    }
    if observe {
        let m = crate::obs::metrics::metrics();
        m.quant_elems.add(fmt, xs.len() as u64);
        m.quant_underflow.add(fmt, underflow);
        m.quant_clip.add(fmt, clip);
    }
}

/// The pre-kernel `quantize_block` (per-block `Vec` + `extend`) — the
/// "old" row of the perf bench pair.
pub fn quantize_block_ref(fmt: Format, xs: &[f32]) -> Vec<f32> {
    let q = BlockQuantizer::new(fmt);
    let mut out = Vec::with_capacity(xs.len());
    for chunk in xs.chunks(fmt.block()) {
        out.extend(q.quantize_block_vec(chunk));
    }
    out
}

/// Quantize a flat slice blockwise (contiguous blocks of fmt.block()).
pub fn quantize_block(fmt: Format, xs: &[f32]) -> Vec<f32> {
    if crate::linalg::kernels::reference_mode() {
        return quantize_block_ref(fmt, xs);
    }
    let mut out = vec![0.0f32; xs.len()];
    quantize_slice_into(fmt, xs, &mut out);
    out
}

/// Quantize a matrix with scale blocks along `axis` (0 = down columns,
/// 1 = along rows).  Axis 1 matches activation quantization (blocks along
/// K for X·W); axis 0 matches weight quantization.
///
/// Axis 1 streams each row through the fused quantizer with one scratch
/// row (f64→f32 cast fused into the same walk); axis 0 strides each
/// column directly through a stack block buffer instead of paying two
/// full transposes and an f32 copy of the whole matrix.  Both paths are
/// bit-identical to the historical implementation (same per-element op
/// sequence in the same order).
pub fn quantize_matrix_along(fmt: Format, a: &Matrix, axis: usize) -> Matrix {
    if crate::linalg::kernels::reference_mode() {
        return quantize_matrix_along_ref(fmt, a, axis);
    }
    let (rows, cols) = (a.rows, a.cols);
    let mut out = Matrix::zeros(rows, cols);
    match axis {
        1 => {
            let mut xrow = vec![0.0f32; cols];
            let mut qrow = vec![0.0f32; cols];
            for r in 0..rows {
                let arow = &a.data[r * cols..(r + 1) * cols];
                for (x, &v) in xrow.iter_mut().zip(arow) {
                    *x = v as f32;
                }
                quantize_slice_into(fmt, &xrow, &mut qrow);
                for (o, &q) in out.data[r * cols..(r + 1) * cols].iter_mut().zip(&qrow) {
                    *o = q as f64;
                }
            }
        }
        0 => {
            let block = fmt.block();
            // Hard assert (not debug_assert): a future >128 block format
            // would otherwise silently quantize truncated blocks in
            // release builds — the stack buffers below are MAX_BLOCK wide.
            assert!(
                block <= MAX_BLOCK,
                "format block {block} exceeds MAX_BLOCK {MAX_BLOCK}"
            );
            let mut xbuf = [0.0f32; MAX_BLOCK];
            let mut qbuf = [0.0f32; MAX_BLOCK];
            for c in 0..cols {
                let mut r0 = 0;
                while r0 < rows {
                    let len = block.min(rows - r0);
                    for (i, x) in xbuf[..len].iter_mut().enumerate() {
                        *x = a.data[(r0 + i) * cols + c] as f32;
                    }
                    quantize_slice_into(fmt, &xbuf[..len], &mut qbuf[..len]);
                    for (i, &q) in qbuf[..len].iter().enumerate() {
                        out.data[(r0 + i) * cols + c] = q as f64;
                    }
                    r0 += len;
                }
            }
        }
        _ => panic!("axis must be 0 or 1"),
    }
    out
}

/// Pack a matrix into true 4-bit (FP4) / 8-bit (FP8) storage with
/// per-block scales along `axis` — the operand form `linalg::qgemm`
/// contracts natively.  Runs the *same* per-element pipeline as
/// [`quantize_matrix_along`] (identical f64→f32 cast, amax fold order,
/// scale rule, element codec), storing each element's code instead of
/// its dequantized value, so `pack_matrix_along(fmt, a, axis).unpack()`
/// is bit-identical to `quantize_matrix_along(fmt, a, axis)` — the
/// property the qgemm oracle tests pin.
pub fn pack_matrix_along(fmt: Format, a: &Matrix, axis: usize) -> PackedQMatrix {
    assert!(axis == 0 || axis == 1, "axis must be 0 or 1");
    let (lines, line_len) = if axis == 1 {
        (a.rows, a.cols)
    } else {
        (a.cols, a.rows)
    };
    let stride = pack::code_stride(fmt, line_len);
    let block = fmt.block();
    let bpl = line_len.div_ceil(block);
    let mut codes = vec![0u8; lines * stride];
    let mut scales = vec![0.0f32; lines * bpl];
    let mut xline = vec![0.0f32; line_len];
    let observe = crate::obs::enabled();
    let (mut underflow, mut clip) = (0u64, 0u64);
    for line in 0..lines {
        if axis == 1 {
            for (x, &v) in xline.iter_mut().zip(&a.data[line * a.cols..(line + 1) * a.cols]) {
                *x = v as f32;
            }
        } else {
            for (r, x) in xline.iter_mut().enumerate() {
                *x = a.data[r * a.cols + line] as f32;
            }
        }
        let lcodes = &mut codes[line * stride..(line + 1) * stride];
        let lscales = &mut scales[line * bpl..(line + 1) * bpl];
        for (bi, xc) in xline.chunks(block).enumerate() {
            let mut amax = 0.0f32;
            for &x in xc {
                amax = amax.max(x.abs());
            }
            let s = fmt.scale(amax);
            lscales[bi] = s;
            for (i, &x) in xc.iter().enumerate() {
                let e = fmt.elem(x / s);
                pack::encode_into(fmt, lcodes, bi * block + i, e);
                if observe {
                    // Same tallies as quantize_slice_into, on the same
                    // product e·s the dequantized path would store.
                    underflow += u64::from(x != 0.0 && e * s == 0.0);
                    clip += u64::from(x.abs() > s * fmt.elem_max());
                }
            }
        }
    }
    if observe {
        let m = crate::obs::metrics::metrics();
        m.quant_elems.add(fmt, (lines * line_len) as u64);
        m.quant_underflow.add(fmt, underflow);
        m.quant_clip.add(fmt, clip);
    }
    PackedQMatrix {
        fmt,
        rows: a.rows,
        cols: a.cols,
        axis,
        codes,
        scales,
    }
}

/// The pre-kernel `quantize_matrix_along` (whole-matrix f32 copy; axis
/// 0 via transpose → rows → transpose) — perf-bench baseline.
pub fn quantize_matrix_along_ref(fmt: Format, a: &Matrix, axis: usize) -> Matrix {
    let f32s: Vec<f32> = a.data.iter().map(|&x| x as f32).collect();
    match axis {
        1 => {
            let mut out = Vec::with_capacity(f32s.len());
            for r in 0..a.rows {
                let row = &f32s[r * a.cols..(r + 1) * a.cols];
                out.extend(quantize_block_ref(fmt, row));
            }
            Matrix::from_vec(a.rows, a.cols, out.iter().map(|&x| x as f64).collect())
        }
        0 => {
            let t = a.transpose();
            quantize_matrix_along_ref(fmt, &t, 1).transpose()
        }
        _ => panic!("axis must be 0 or 1"),
    }
}

/// Bias / error statistics of a quantization pass (Fig. 4 metrics).
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    /// ‖Q(A) − A‖_F / ‖A‖_F
    pub rel_frob_err: f64,
    /// fraction of non-zero inputs clipped to exactly 0 (underflow bias)
    pub underflow_frac: f64,
    /// mean relative error per input-magnitude decile (small → large)
    pub decile_rel_err: Vec<f64>,
    /// fraction of elements that changed at all
    pub changed_frac: f64,
}

pub fn quant_stats(a: &Matrix, q: &Matrix) -> QuantStats {
    assert_eq!((a.rows, a.cols), (q.rows, q.cols));
    let n = a.data.len();
    let mut err2 = 0.0;
    let mut norm2 = 0.0;
    let mut nz = 0usize;
    let mut clipped = 0usize;
    let mut changed = 0usize;

    // deciles of |a|
    let mut mags: Vec<f64> = a.data.iter().map(|x| x.abs()).collect();
    // total_cmp, not partial_cmp().unwrap(): a single NaN input must
    // not panic the stats pass (same bug class as the Jacobi σ sort).
    mags.sort_by(f64::total_cmp);
    let decile_edges: Vec<f64> = (1..10).map(|i| mags[i * n / 10]).collect();
    let mut dec_err = vec![0.0f64; 10];
    let mut dec_cnt = vec![0usize; 10];

    for (&x, &y) in a.data.iter().zip(&q.data) {
        let e = y - x;
        err2 += e * e;
        norm2 += x * x;
        if x != 0.0 {
            nz += 1;
            if y == 0.0 {
                clipped += 1;
            }
            let d = decile_edges
                .iter()
                .position(|&edge| x.abs() <= edge)
                .unwrap_or(9);
            dec_err[d] += (e / x).abs();
            dec_cnt[d] += 1;
        }
        if e != 0.0 {
            changed += 1;
        }
    }
    QuantStats {
        rel_frob_err: (err2 / norm2.max(1e-300)).sqrt(),
        underflow_frac: clipped as f64 / nz.max(1) as f64,
        decile_rel_err: dec_err
            .iter()
            .zip(&dec_cnt)
            .map(|(e, &c)| if c > 0 { e / c as f64 } else { 0.0 })
            .collect(),
        changed_frac: changed as f64 / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn block_scale_uses_block_max() {
        // A single huge value in a block coarsens everything around it.
        let mut xs = vec![0.01f32; 32];
        xs[0] = 6.0;
        let q = quantize_block(Format::Mxfp4, &xs);
        // 0.01 with scale 2^0=1: fp4(0.01) = 0 → clipped.
        assert_eq!(q[5], 0.0);
        assert_eq!(q[0], 6.0);
        // Same small values alone survive (scale adapts down).
        let q2 = quantize_block(Format::Mxfp4, &vec![0.01f32; 32]);
        assert!(q2[5] != 0.0);
    }

    #[test]
    fn fused_path_is_bit_identical_to_reference() {
        // The fused single-walk quantizer and the historical per-block
        // Vec path must agree bit-for-bit, including partial tail
        // blocks; same for both matrix axes (the strided axis-0 walk
        // replaces two transposes).
        let mut rng = Rng::new(7);
        for fmt in [Format::Mxfp4, Format::Nvfp4, Format::Fp8, Format::PaperFp4] {
            for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 127, 128, 129, 1000] {
                let xs: Vec<f32> = (0..len).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
                assert_eq!(quantize_block(fmt, &xs), quantize_block_ref(fmt, &xs), "{len}");
            }
            for (m, n) in [(1, 7), (5, 1), (13, 40), (33, 17), (64, 48)] {
                let a = Matrix::gaussian(&mut rng, m, n, 1.5);
                for axis in [0, 1] {
                    assert_eq!(
                        quantize_matrix_along(fmt, &a, axis),
                        quantize_matrix_along_ref(fmt, &a, axis),
                        "{} {m}x{n} axis {axis}",
                        fmt.name()
                    );
                }
            }
        }
    }

    #[test]
    fn packed_decode_is_bit_identical_to_quantize() {
        // ISSUE 9 property test: pack(A).unpack() must equal
        // quantize_matrix_along(fmt, A, axis) *bitwise* (to_bits, so a
        // −0.0/+0.0 swap cannot hide behind f64 ==) for all formats,
        // both axes, partial tail blocks, and 0-row/0-col edge shapes.
        let mut rng = Rng::new(21);
        for fmt in Format::ALL {
            for (m, n) in [
                (0usize, 0usize),
                (0, 5),
                (5, 0),
                (1, 1),
                (1, 17),
                (17, 1),
                (13, 40),
                (33, 31),
                (64, 129),
                (130, 48),
            ] {
                let a = Matrix::gaussian(&mut rng, m, n, 2.0);
                for axis in [0, 1] {
                    let q = quantize_matrix_along(fmt, &a, axis);
                    let p = pack_matrix_along(fmt, &a, axis).unpack();
                    assert_eq!((p.rows, p.cols), (q.rows, q.cols));
                    for (i, (&pv, &qv)) in p.data.iter().zip(&q.data).enumerate() {
                        assert_eq!(
                            pv.to_bits(),
                            qv.to_bits(),
                            "{} {m}x{n} axis {axis} elem {i}: {pv} vs {qv}",
                            fmt.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_decode_preserves_negative_underflow_sign() {
        // Negative values that underflow to zero quantize to −0.0; the
        // nibble round-trip must keep the sign bit.
        let mut a = Matrix::zeros(1, 32);
        a.data[0] = 100.0;
        a.data[1] = -1e-4;
        let q = quantize_matrix_along(Format::Mxfp4, &a, 1);
        assert!(q.data[1] == 0.0 && q.data[1].is_sign_negative());
        let p = pack_matrix_along(Format::Mxfp4, &a, 1).unpack();
        assert_eq!(p.data[1].to_bits(), q.data[1].to_bits());
    }

    #[test]
    fn every_format_fits_max_block() {
        // Guards the axis-0 stack buffers: quantize_matrix_along hard-
        // asserts block ≤ MAX_BLOCK, so a new wider format must fail
        // here (and there) instead of silently truncating blocks.
        for fmt in Format::ALL {
            assert!(fmt.block() <= MAX_BLOCK, "{}", fmt.name());
        }
    }

    #[test]
    fn quant_stats_tolerates_nan_inputs() {
        // Regression: the magnitude-decile sort used partial_cmp().
        // unwrap(), which panics on NaN — total_cmp must not.
        let mut a = Matrix::zeros(4, 8);
        for (i, v) in a.data.iter_mut().enumerate() {
            *v = i as f64 - 11.0;
        }
        a.data[5] = f64::NAN;
        let q = a.clone();
        let st = quant_stats(&a, &q);
        assert!(st.decile_rel_err.len() == 10);
        assert!(st.rel_frob_err.is_nan() || st.rel_frob_err >= 0.0);
    }

    #[test]
    fn quantize_slice_into_writes_caller_buffer() {
        let mut rng = Rng::new(8);
        let xs: Vec<f32> = (0..100).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let mut out = vec![9.0f32; 100];
        quantize_slice_into(Format::Nvfp4, &xs, &mut out);
        assert_eq!(out, quantize_block(Format::Nvfp4, &xs));
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(0);
        for fmt in [Format::Mxfp4, Format::Nvfp4, Format::Fp8] {
            let xs: Vec<f32> = (0..256).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let q1 = quantize_block(fmt, &xs);
            let q2 = quantize_block(fmt, &q1);
            // One more pass may re-scale but values stay on grid·scale;
            // for MX (power-of-two scales) it is exactly idempotent.
            if fmt == Format::Mxfp4 {
                assert_eq!(q1, q2);
            }
        }
    }

    #[test]
    fn axis_0_equals_transposed_axis_1() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(&mut rng, 64, 48, 1.0);
        let q0 = quantize_matrix_along(Format::Nvfp4, &a, 0);
        let q1t = quantize_matrix_along(Format::Nvfp4, &a.transpose(), 1).transpose();
        assert_eq!(q0, q1t);
    }

    #[test]
    fn error_bound_per_block() {
        // |q - x| <= scale * elem_step_max/2 per element (worst binade step).
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..320).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        let q = quantize_block(Format::Mxfp4, &xs);
        for (chunk_x, chunk_q) in xs.chunks(32).zip(q.chunks(32)) {
            let amax = chunk_x.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let s = Format::Mxfp4.scale(amax);
            for (&x, &y) in chunk_x.iter().zip(chunk_q) {
                // max step on the E2M1 grid is 2 (between 4 and 6);
                // saturation can add up to amax - 6s.
                let bound = (s * 1.0).max(amax - 6.0 * s) + 1e-6;
                assert!((y - x).abs() <= bound, "x={x} y={y} s={s}");
            }
        }
    }

    #[test]
    fn wide_distribution_increases_underflow() {
        // Paper §2.3: wider spread within a block → more small-value
        // clipping.  Narrow Gaussian vs heavy-tailed mixture.
        let mut rng = Rng::new(3);
        let narrow = Matrix::gaussian(&mut rng, 32, 64, 1.0);
        let mut wide = narrow.clone();
        for i in 0..wide.rows {
            wide[(i, 0)] = 50.0; // one outlier per 64-block row… 2 blocks/row
            wide[(i, 32)] = 50.0;
        }
        let qn = quantize_matrix_along(Format::Mxfp4, &narrow, 1);
        let qw = quantize_matrix_along(Format::Mxfp4, &wide, 1);
        let sn = quant_stats(&narrow, &qn);
        let sw = quant_stats(&wide, &qw);
        assert!(
            sw.underflow_frac > sn.underflow_frac * 3.0,
            "wide {} vs narrow {}",
            sw.underflow_frac,
            sn.underflow_frac
        );
    }

    #[test]
    fn smaller_magnitudes_get_larger_relative_error() {
        // The bias of Fig. 4B: relative error decreasing in magnitude.
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(&mut rng, 128, 128, 1.0);
        let q = quantize_matrix_along(Format::Mxfp4, &a, 1);
        let st = quant_stats(&a, &q);
        let small = st.decile_rel_err[0];
        let large = st.decile_rel_err[9];
        assert!(
            small > 2.0 * large,
            "decile errs {:?}",
            st.decile_rel_err
        );
    }
}
