//! Data substrate: the synthetic corpus that stands in for DCLM and the
//! six GLUE-shaped downstream probe tasks (DESIGN.md §4 Substitutions).

pub mod batcher;
pub mod corpus;
pub mod tasks;

pub use batcher::BatchIterator;
pub use corpus::{Corpus, CorpusConfig};
pub use tasks::{Task, TaskExample, TaskKind, ALL_TASKS};
