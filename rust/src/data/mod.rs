//! Data substrate: the synthetic corpus that stands in for DCLM, the
//! six GLUE-shaped downstream probe tasks (DESIGN.md §4 Substitutions),
//! and the streamed held-out validation-split loader of the native
//! loop's eval harness.

pub mod batcher;
pub mod corpus;
pub mod evalsplit;
pub mod tasks;

pub use batcher::BatchIterator;
pub use corpus::{Corpus, CorpusConfig};
pub use evalsplit::{scan_eval_split, EvalBatchSpec};
pub use tasks::{Task, TaskExample, TaskKind, ALL_TASKS};
