//! Held-out validation-split loader: a directory of `.npy` activation
//! batches, scanned header-only and streamed on demand through
//! [`NpyReader`] — the data side of the native loop's eval harness.
//!
//! Layout contract (mirrors `scan_checkpoint_dir`'s for weights):
//!
//! * a 2-D `(b, d)` blob is one batch of `b` probe activations of
//!   width `d`;
//! * a 3-D `(N, b, d)` blob — the layout JAX-stacked eval shards use —
//!   unstacks into N batches named `<stem>.<i>`;
//! * 1-D vectors and scalars are skipped.
//!
//! Batches are sorted by file name, so the split order (and therefore
//! every reduction over it) is deterministic.  A batch applies to every
//! layer whose input dimension equals its width `d`, which lets one
//! split directory serve models whose layers disagree on input width
//! (e.g. the 4·d_model rows of an FFN-out projection).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Matrix;
use crate::util::npy::{NpyReader, ReaderCache};

/// One held-out batch: shape known from the scan, payload streamed at
/// use through the worker's [`ReaderCache`].
#[derive(Clone, Debug)]
pub struct EvalBatchSpec {
    pub name: String,
    /// Probe activations in the batch.
    pub rows: usize,
    /// Activation width — matched against layer input dims.
    pub cols: usize,
    path: PathBuf,
    /// Flat element offset within the payload (`i·b·d` for member i of
    /// a stacked blob).
    base_elem: usize,
}

impl EvalBatchSpec {
    /// Materialize the batch as a rows×cols matrix.
    pub fn read(&self, cache: &mut ReaderCache) -> Result<Matrix> {
        let rdr = cache.reader(&self.path)?;
        let data = rdr.read_f64_at(self.base_elem, self.rows * self.cols)?;
        let x = Matrix::from_vec(self.rows, self.cols, data);
        if !x.data.iter().all(|v| v.is_finite()) {
            bail!(
                "non-finite activation values in eval batch {}: {}",
                self.name,
                self.path.display()
            );
        }
        Ok(x)
    }
}

/// Scan every `.npy` batch under `dir` without reading any payload.
pub fn scan_eval_split(dir: impl AsRef<Path>) -> Result<Vec<EvalBatchSpec>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("read eval split dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "npy"))
        .collect();
    paths.sort();
    let mut out = Vec::new();
    for path in paths {
        let rdr = NpyReader::open(&path).with_context(|| format!("batch {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        match rdr.shape() {
            &[rows, cols] if rows >= 1 && cols >= 2 => out.push(EvalBatchSpec {
                name,
                rows,
                cols,
                path,
                base_elem: 0,
            }),
            &[stack, rows, cols] if rows >= 1 && cols >= 2 => {
                for i in 0..stack {
                    out.push(EvalBatchSpec {
                        name: format!("{name}.{i}"),
                        rows,
                        cols,
                        path: path.clone(),
                        base_elem: i * rows * cols,
                    });
                }
            }
            _ => continue, // scalars, 1-D vectors, degenerate widths
        }
    }
    if out.is_empty() {
        bail!(
            "no 2-D or stacked 3-D .npy activation batches under {}",
            dir.display()
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::npy::{write_npy, NpyArray};
    use crate::util::prng::Rng;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn scan_unstacks_and_filters() {
        let dir = test_dir("metis_evalsplit_scan");
        let mut rng = Rng::new(0);
        let flat = Matrix::gaussian(&mut rng, 4, 8, 1.0);
        write_npy(
            dir.join("b_flat.npy"),
            &NpyArray::f32(vec![4, 8], flat.data.iter().map(|&v| v as f32).collect()),
        )
        .unwrap();
        // A stacked shard of 3 batches.
        let stacked: Vec<f32> = (0..3 * 2 * 8).map(|i| i as f32 * 0.25).collect();
        write_npy(dir.join("a_stack.npy"), &NpyArray::f32(vec![3, 2, 8], stacked.clone())).unwrap();
        // Vectors and scalars are skipped.
        write_npy(dir.join("v.npy"), &NpyArray::f32(vec![8], vec![0.0; 8])).unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();

        let specs = scan_eval_split(&dir).unwrap();
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        // Name-sorted: the stacked shard comes first.
        assert_eq!(names, vec!["a_stack.0", "a_stack.1", "a_stack.2", "b_flat"]);
        let mut cache = ReaderCache::new();
        for (i, spec) in specs[..3].iter().enumerate() {
            assert_eq!((spec.rows, spec.cols), (2, 8));
            let x = spec.read(&mut cache).unwrap();
            assert_eq!(x.data[0], stacked[i * 16] as f64);
        }
        assert_eq!(cache.opens(), 1, "stacked members share one reader");
        let x = specs[3].read(&mut cache).unwrap();
        for (a, b) in x.data.iter().zip(&flat.data) {
            assert_eq!(*a, *b as f32 as f64);
        }

        // An empty dir is an error, not an empty split.
        let empty = test_dir("metis_evalsplit_empty");
        assert!(scan_eval_split(&empty).is_err());
    }

    #[test]
    fn non_finite_batches_are_rejected_by_name() {
        let dir = test_dir("metis_evalsplit_nan");
        write_npy(
            dir.join("bad.npy"),
            &NpyArray::f32(vec![2, 4], vec![1.0, f32::NAN, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0]),
        )
        .unwrap();
        let specs = scan_eval_split(&dir).unwrap();
        let err = specs[0].read(&mut ReaderCache::new()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("non-finite") && msg.contains("bad"), "{msg}");
    }
}
