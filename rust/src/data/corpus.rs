//! Synthetic corpus: a probabilistic CFG over a Zipf-distributed lexicon.
//!
//! Stands in for the paper's DCLM pretraining data (DESIGN.md §4): the
//! token process is (a) learnable — grammar gives exploitable structure,
//! so cross-entropy drops well below uniform; (b) long-tailed — Zipfian
//! word frequencies reproduce the rare-token mechanism the anisotropy
//! analysis builds on (§5 Related Work ties outlier dimensions to token
//! frequency imbalance).
//!
//! Grammar (terminals are part-of-speech pools, words drawn Zipf within
//! each pool):
//!
//! ```text
//! S  → NP VP END
//! NP → DET NOUN | DET ADJ NOUN | NAME
//! VP → VERB NP | VERB ADV | VERB NP PP | VERB
//! PP → PREP NP
//! ```

use crate::util::prng::{Rng, ZipfTable};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const END: i32 = 3; // sentence terminator ('.')
pub const QMARK: i32 = 4; // question terminator
pub const NOT: i32 = 5; // negation marker (used by NLI-like tasks)
const SPECIALS: usize = 6;

/// Checked usize→i32 for token ids and pool offsets.  Pool extents are
/// bounded by the vocab validated in [`Corpus::new`], so a failure here
/// is a constructor bug, not a data condition.
fn to_tok(v: usize) -> i32 {
    i32::try_from(v).expect("token id fits i32: vocab bounded at construction")
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pos {
    Det,
    Adj,
    Noun,
    Verb,
    Adv,
    Prep,
    Name,
}

/// A contiguous id range [start, start+len) for one part of speech.
#[derive(Clone, Debug)]
pub struct Pool {
    pub pos: Pos,
    pub start: i32,
    pub len: usize,
    zipf: ZipfTable,
}

impl Pool {
    fn new(pos: Pos, start: i32, len: usize, zipf_s: f64) -> Self {
        Self {
            pos,
            start,
            len,
            zipf: ZipfTable::new(len.max(1), zipf_s),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> i32 {
        self.start + to_tok(self.zipf.sample(rng))
    }

    /// Rank of a token within the pool (0 = most frequent), if a member.
    pub fn rank_of(&self, tok: i32) -> Option<usize> {
        let off = tok - self.start;
        (0..to_tok(self.len)).contains(&off).then_some(off as usize)
    }

    /// The token at a given frequency rank.
    pub fn at_rank(&self, rank: usize) -> i32 {
        self.start + to_tok(rank.min(self.len - 1))
    }
}

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub zipf_s: f64,
    pub seed: u64,
}

impl CorpusConfig {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self {
            vocab,
            zipf_s: 1.3,
            seed,
        }
    }
}

/// The corpus generator: deterministic, seekable by document index.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub cfg: CorpusConfig,
    pub det: Pool,
    pub adj: Pool,
    pub noun: Pool,
    pub verb: Pool,
    pub adv: Pool,
    pub prep: Pool,
    pub name: Pool,
    base: Rng,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        assert!(cfg.vocab >= 64, "vocab too small for the grammar pools");
        assert!(
            i32::try_from(cfg.vocab).is_ok(),
            "vocab must fit i32 token ids"
        );
        let usable = cfg.vocab - SPECIALS;
        // Fixed small closed classes, Zipfian open classes.
        let n_det = 4;
        let n_prep = 6;
        let open = usable - n_det - n_prep;
        let n_noun = open * 35 / 100;
        let n_verb = open * 20 / 100;
        let n_adj = open * 20 / 100;
        let n_adv = open * 10 / 100;
        let n_name = open - n_noun - n_verb - n_adj - n_adv;

        let mut at = to_tok(SPECIALS);
        let mut take = |pos, len: usize, s: f64| {
            let p = Pool::new(pos, at, len, s);
            at += to_tok(len);
            p
        };
        let det = take(Pos::Det, n_det, 1.0);
        let prep = take(Pos::Prep, n_prep, 1.0);
        let adj = take(Pos::Adj, n_adj, cfg.zipf_s);
        let noun = take(Pos::Noun, n_noun, cfg.zipf_s);
        let verb = take(Pos::Verb, n_verb, cfg.zipf_s);
        let adv = take(Pos::Adv, n_adv, cfg.zipf_s);
        let name = take(Pos::Name, n_name, cfg.zipf_s);
        assert!(at as usize <= cfg.vocab);

        let base = Rng::new(cfg.seed ^ 0x4D45_5449_53);
        Self {
            cfg,
            det,
            adj,
            noun,
            verb,
            adv,
            prep,
            name,
            base,
        }
    }

    /// Independent RNG stream for document `idx` of a named split.
    pub fn doc_rng(&self, split: u64, idx: u64) -> Rng {
        self.base.fold_in(split.wrapping_mul(0x1000_0000_0000) ^ idx)
    }

    // -- grammar ---------------------------------------------------------------

    pub fn gen_np(&self, rng: &mut Rng, out: &mut Vec<i32>) {
        match rng.below(5) {
            0 | 1 => {
                out.push(self.det.sample(rng));
                out.push(self.noun.sample(rng));
            }
            2 | 3 => {
                out.push(self.det.sample(rng));
                out.push(self.adj.sample(rng));
                out.push(self.noun.sample(rng));
            }
            _ => out.push(self.name.sample(rng)),
        }
    }

    pub fn gen_vp(&self, rng: &mut Rng, out: &mut Vec<i32>) {
        match rng.below(6) {
            0 | 1 => {
                out.push(self.verb.sample(rng));
                self.gen_np(rng, out);
            }
            2 => {
                out.push(self.verb.sample(rng));
                out.push(self.adv.sample(rng));
            }
            3 | 4 => {
                out.push(self.verb.sample(rng));
                self.gen_np(rng, out);
                out.push(self.prep.sample(rng));
                self.gen_np(rng, out);
            }
            _ => out.push(self.verb.sample(rng)),
        }
    }

    /// One grammatical sentence: NP VP END.
    pub fn gen_sentence(&self, rng: &mut Rng) -> Vec<i32> {
        let mut s = Vec::with_capacity(10);
        self.gen_np(rng, &mut s);
        self.gen_vp(rng, &mut s);
        s.push(END);
        s
    }

    /// A token stream of at least `min_len` tokens (BOS-prefixed sentences).
    pub fn gen_stream(&self, rng: &mut Rng, min_len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(min_len + 16);
        out.push(BOS);
        while out.len() < min_len {
            out.extend(self.gen_sentence(rng));
        }
        out
    }

    /// Which pool does a token belong to?
    pub fn pos_of(&self, tok: i32) -> Option<Pos> {
        for p in [
            &self.det, &self.prep, &self.adj, &self.noun, &self.verb,
            &self.adv, &self.name,
        ] {
            if p.rank_of(tok).is_some() {
                return Some(p.pos);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::new(256, 7))
    }

    #[test]
    fn deterministic_streams() {
        let c = corpus();
        let a = c.gen_stream(&mut c.doc_rng(0, 42), 100);
        let b = c.gen_stream(&mut c.doc_rng(0, 42), 100);
        assert_eq!(a, b);
        let d = c.gen_stream(&mut c.doc_rng(0, 43), 100);
        assert_ne!(a, d);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        let s = c.gen_stream(&mut c.doc_rng(1, 0), 2000);
        assert!(s.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn sentences_are_grammatical_shape() {
        let c = corpus();
        let mut rng = c.doc_rng(2, 0);
        for _ in 0..100 {
            let s = c.gen_sentence(&mut rng);
            assert_eq!(*s.last().unwrap(), END);
            assert!(s.len() >= 3);
            // first token opens an NP: DET or NAME
            let pos = c.pos_of(s[0]).unwrap();
            assert!(matches!(pos, Pos::Det | Pos::Name), "{pos:?}");
        }
    }

    #[test]
    fn zipf_frequencies_long_tailed() {
        let c = corpus();
        let s = c.gen_stream(&mut c.doc_rng(3, 0), 50_000);
        let mut counts = vec![0usize; 256];
        for &t in &s {
            counts[t as usize] += 1;
        }
        // head noun much more frequent than deep-tail noun
        let head = counts[c.noun.at_rank(0) as usize];
        let tail = counts[c.noun.at_rank(c.noun.len - 1) as usize];
        assert!(head > 10 * (tail + 1), "head {head} tail {tail}");
    }

    #[test]
    fn pools_disjoint_and_cover() {
        let c = corpus();
        let mut seen = vec![false; 256];
        for p in [&c.det, &c.prep, &c.adj, &c.noun, &c.verb, &c.adv, &c.name] {
            for t in p.start..p.start + to_tok(p.len) {
                assert!(!seen[t as usize], "overlap at {t}");
                seen[t as usize] = true;
            }
        }
        assert!(!seen[PAD as usize] && !seen[END as usize]);
    }
}
