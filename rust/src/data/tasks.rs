//! Downstream probe tasks with GLUE-shaped semantics (DESIGN.md §4).
//!
//! The paper evaluates on CoLA / SST-2 / MRPC / MNLI / QNLI / RTE.  We
//! cannot ship GLUE, so each task is re-created synthetically *with the
//! same decision shape* over the pretraining grammar — what the probes
//! measure is how much linearly-decodable structure the (quantized)
//! pretraining preserved, which is exactly what the paper uses GLUE for:
//!
//! * `ColaLike`  — acceptability: grammatical vs corrupted word order.
//! * `Sst2Like`  — polarity: sentence lexicalised from one of two
//!                 disjoint "valence" halves of the adjective pool.
//! * `MrpcLike`  — paraphrase: pair is a near-relexicalisation vs an
//!                 unrelated sentence (SEP-joined).
//! * `MnliLike`  — 3-class NLI: hypothesis entails / contradicts (NOT
//!                 marker) / is neutral w.r.t. the premise.
//! * `QnliLike`  — question-answer relevance: QMARK query mentions a
//!                 noun that does / does not occur in the sentence.
//! * `RteLike`   — small-data entailment: hypothesis drops the premise's
//!                 adjective (entailed) vs swaps its noun (not entailed).

use crate::data::corpus::{Corpus, END, NOT, QMARK, SEP};
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    ColaLike,
    Sst2Like,
    MrpcLike,
    MnliLike,
    QnliLike,
    RteLike,
}

pub const ALL_TASKS: [TaskKind; 6] = [
    TaskKind::ColaLike,
    TaskKind::Sst2Like,
    TaskKind::MrpcLike,
    TaskKind::MnliLike,
    TaskKind::QnliLike,
    TaskKind::RteLike,
];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::ColaLike => "CoLA*",
            TaskKind::Sst2Like => "SST-2*",
            TaskKind::MrpcLike => "MRPC*",
            TaskKind::MnliLike => "MNLI*",
            TaskKind::QnliLike => "QNLI*",
            TaskKind::RteLike => "RTE*",
        }
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            TaskKind::ColaLike => "CoLA",
            TaskKind::Sst2Like => "SST-2",
            TaskKind::MrpcLike => "MRPC",
            TaskKind::MnliLike => "MNLI",
            TaskKind::QnliLike => "QNLI",
            TaskKind::RteLike => "RTE",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            TaskKind::MnliLike => 3,
            _ => 2,
        }
    }

    /// Number of train examples (RTE is deliberately small-data, as in
    /// GLUE; overall sizes trade probe noise for feature-extraction cost
    /// — extraction through the engine dominates the table benches).
    pub fn n_train(&self) -> usize {
        match self {
            TaskKind::RteLike => 192,
            _ => 512,
        }
    }

    pub fn n_eval(&self) -> usize {
        256
    }
}

#[derive(Clone, Debug)]
pub struct TaskExample {
    pub tokens: Vec<i32>, // padded to seq_len
    pub label: usize,
}

pub struct Task {
    pub kind: TaskKind,
    pub seq_len: usize,
    pub train: Vec<TaskExample>,
    pub eval: Vec<TaskExample>,
}

fn pad_to(mut toks: Vec<i32>, seq_len: usize) -> Vec<i32> {
    toks.truncate(seq_len);
    while toks.len() < seq_len {
        toks.push(super::corpus::PAD);
    }
    toks
}

/// Shift a token to a nearby frequency rank within its pool (a crude
/// "synonym": distributionally similar word).
fn synonym(c: &Corpus, tok: i32, rng: &mut Rng) -> i32 {
    for pool in [&c.adj, &c.noun, &c.verb, &c.adv, &c.name] {
        if let Some(r) = pool.rank_of(tok) {
            let delta = 1 + rng.usize(3);
            let nr = if rng.below(2) == 0 {
                r.saturating_sub(delta)
            } else {
                (r + delta).min(pool.len - 1)
            };
            return pool.at_rank(nr);
        }
    }
    tok
}

fn gen_example(c: &Corpus, kind: TaskKind, rng: &mut Rng, seq_len: usize) -> TaskExample {
    match kind {
        TaskKind::ColaLike => {
            let mut s = c.gen_sentence(rng);
            let label = rng.usize(2);
            if label == 0 {
                // corrupt: swap two adjacent non-terminal tokens
                if s.len() >= 4 {
                    let i = rng.usize(s.len() - 2);
                    s.swap(i, i + 1);
                }
            }
            TaskExample {
                tokens: pad_to(s, seq_len),
                label,
            }
        }
        TaskKind::Sst2Like => {
            // polarity = which half of the adjective pool lexicalises it;
            // inject 2 polarity adjectives so the signal is present.
            let label = rng.usize(2);
            let half = c.adj.len / 2;
            let pick = |rng: &mut Rng| {
                let r = rng.usize(half.max(1));
                c.adj.at_rank(if label == 1 { r } else { half + r })
            };
            let mut s = Vec::new();
            s.push(c.det.sample(rng));
            s.push(pick(rng));
            s.push(c.noun.sample(rng));
            s.push(c.verb.sample(rng));
            s.push(c.det.sample(rng));
            s.push(pick(rng));
            s.push(c.noun.sample(rng));
            s.push(END);
            TaskExample {
                tokens: pad_to(s, seq_len),
                label,
            }
        }
        TaskKind::MrpcLike => {
            let s1 = c.gen_sentence(rng);
            let label = rng.usize(2);
            let s2 = if label == 1 {
                // paraphrase: synonym-shift open-class words
                s1.iter().map(|&t| synonym(c, t, rng)).collect()
            } else {
                c.gen_sentence(rng)
            };
            let mut pair = s1;
            pair.push(SEP);
            pair.extend(s2);
            TaskExample {
                tokens: pad_to(pair, seq_len),
                label,
            }
        }
        TaskKind::MnliLike => {
            // premise: DET ADJ NOUN VERB DET NOUN END
            let det1 = c.det.sample(rng);
            let adj = c.adj.sample(rng);
            let subj = c.noun.sample(rng);
            let verb = c.verb.sample(rng);
            let det2 = c.det.sample(rng);
            let obj = c.noun.sample(rng);
            let premise = vec![det1, adj, subj, verb, det2, obj, END];
            let label = rng.usize(3); // 0 entail, 1 neutral, 2 contradict
            let hypothesis = match label {
                0 => vec![det1, subj, verb, det2, obj, END], // drop ADJ: entailed
                1 => {
                    // same subject, unrelated predicate
                    let mut h = vec![det1, subj];
                    c.gen_vp(rng, &mut h);
                    h.push(END);
                    h
                }
                _ => vec![det1, subj, NOT, verb, det2, obj, END], // negated
            };
            let mut pair = premise;
            pair.push(SEP);
            pair.extend(hypothesis);
            TaskExample {
                tokens: pad_to(pair, seq_len),
                label,
            }
        }
        TaskKind::QnliLike => {
            let s = c.gen_sentence(rng);
            let nouns: Vec<i32> = s
                .iter()
                .cloned()
                .filter(|&t| c.noun.rank_of(t).is_some() || c.name.rank_of(t).is_some())
                .collect();
            let label = rng.usize(2);
            let q_noun = if label == 1 && !nouns.is_empty() {
                nouns[rng.usize(nouns.len())]
            } else {
                // a noun not in the sentence
                loop {
                    let t = c.noun.sample(rng);
                    if !s.contains(&t) {
                        break t;
                    }
                }
            };
            let mut pair = vec![c.verb.sample(rng), q_noun, QMARK, SEP];
            pair.extend(s);
            TaskExample {
                tokens: pad_to(pair, seq_len),
                label,
            }
        }
        TaskKind::RteLike => {
            let det = c.det.sample(rng);
            let adj = c.adj.sample(rng);
            let subj = c.noun.sample(rng);
            let mut premise = vec![det, adj, subj];
            c.gen_vp(rng, &mut premise);
            premise.push(END);
            let label = rng.usize(2);
            let hyp = if label == 1 {
                let mut h = premise.clone();
                h.remove(1); // drop ADJ → entailed
                h
            } else {
                let mut h = premise.clone();
                h[2] = loop {
                    let t = c.noun.sample(rng);
                    if t != subj {
                        break t;
                    }
                }; // different subject → not entailed
                h
            };
            let mut pair = premise;
            pair.push(SEP);
            pair.extend(hyp);
            TaskExample {
                tokens: pad_to(pair, seq_len),
                label,
            }
        }
    }
}

impl Task {
    /// Build a task dataset; `split_seed` distinguishes experiment reruns.
    pub fn generate(c: &Corpus, kind: TaskKind, seq_len: usize, split_seed: u64) -> Task {
        let gen_set = |n: usize, salt: u64| {
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let mut rng = c.doc_rng(0xD0DA ^ salt ^ split_seed, i as u64 ^ (kind as u64) << 32);
                out.push(gen_example(c, kind, &mut rng, seq_len));
            }
            out
        };
        Task {
            kind,
            seq_len,
            train: gen_set(kind.n_train(), 0x7EA1),
            eval: gen_set(kind.n_eval(), 0xE7A1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::new(256, 3))
    }

    #[test]
    fn all_tasks_generate_valid_examples() {
        let c = corpus();
        for kind in ALL_TASKS {
            let t = Task::generate(&c, kind, 64, 0);
            assert_eq!(t.train.len(), kind.n_train());
            assert_eq!(t.eval.len(), kind.n_eval());
            for ex in t.train.iter().chain(&t.eval) {
                assert_eq!(ex.tokens.len(), 64);
                assert!(ex.label < kind.n_classes());
                assert!(ex.tokens.iter().all(|&x| (0..256).contains(&x)));
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let c = corpus();
        for kind in ALL_TASKS {
            let t = Task::generate(&c, kind, 64, 0);
            let mut counts = vec![0usize; kind.n_classes()];
            for ex in &t.train {
                counts[ex.label] += 1;
            }
            let lo = *counts.iter().min().unwrap() as f64;
            let hi = *counts.iter().max().unwrap() as f64;
            assert!(lo / hi > 0.6, "{kind:?}: {counts:?}");
        }
    }

    #[test]
    fn sst2_signal_exists() {
        // Polarity must be decodable from token identities alone.
        let c = corpus();
        let t = Task::generate(&c, TaskKind::Sst2Like, 64, 0);
        let half = c.adj.len / 2;
        let mut correct = 0;
        for ex in &t.eval {
            let vote = ex
                .tokens
                .iter()
                .filter_map(|&tok| c.adj.rank_of(tok))
                .map(|r| if r < half { 1 } else { 0 })
                .sum::<usize>();
            let n_adj = ex
                .tokens
                .iter()
                .filter(|&&tok| c.adj.rank_of(tok).is_some())
                .count();
            let pred = (vote * 2 > n_adj) as usize;
            if pred == ex.label {
                correct += 1;
            }
        }
        assert!(correct as f64 / t.eval.len() as f64 > 0.95);
    }

    #[test]
    fn deterministic_across_builds() {
        let c = corpus();
        let a = Task::generate(&c, TaskKind::MnliLike, 32, 1);
        let b = Task::generate(&c, TaskKind::MnliLike, 32, 1);
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
        let d = Task::generate(&c, TaskKind::MnliLike, 32, 2);
        assert_ne!(a.train[0].tokens, d.train[0].tokens);
    }
}
