//! Batch assembly: pack corpus streams into (B, T+1) next-token windows.
//!
//! Deterministic and seekable: batch `i` of split `s` is a pure function
//! of (corpus seed, s, i) — the coordinator's data-loader thread and any
//! resumed run produce identical batches.

use crate::data::corpus::Corpus;

pub struct BatchIterator<'a> {
    corpus: &'a Corpus,
    pub batch: usize,
    pub seq_len: usize,
    split: u64,
    next_idx: u64,
}

impl<'a> BatchIterator<'a> {
    pub fn new(corpus: &'a Corpus, batch: usize, seq_len: usize, split: u64) -> Self {
        Self {
            corpus,
            batch,
            seq_len,
            split,
            next_idx: 0,
        }
    }

    /// Seek to a batch index (for resume).
    pub fn seek(&mut self, batch_idx: u64) {
        self.next_idx = batch_idx * self.batch as u64;
    }

    /// Produce the next (B, T+1) token block, row-major flattened.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * (self.seq_len + 1));
        for _ in 0..self.batch {
            let mut rng = self.corpus.doc_rng(self.split, self.next_idx);
            self.next_idx += 1;
            let stream = self.corpus.gen_stream(&mut rng, self.seq_len + 1);
            out.extend(&stream[..self.seq_len + 1]);
        }
        out
    }

    /// Batch for an explicit index without advancing state.
    pub fn batch_at(&self, batch_idx: u64) -> Vec<i32> {
        let mut it = BatchIterator::new(self.corpus, self.batch, self.seq_len, self.split);
        it.seek(batch_idx);
        it.next_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    #[test]
    fn shapes_and_determinism() {
        let c = Corpus::new(CorpusConfig::new(256, 1));
        let mut it = BatchIterator::new(&c, 4, 32, 0);
        let b0 = it.next_batch();
        assert_eq!(b0.len(), 4 * 33);
        let b1 = it.next_batch();
        assert_ne!(b0, b1);
        // Seekability
        assert_eq!(it.batch_at(0), b0);
        assert_eq!(it.batch_at(1), b1);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let c = Corpus::new(CorpusConfig::new(256, 1));
        let train = BatchIterator::new(&c, 2, 16, 0).next_batch();
        let eval = BatchIterator::new(&c, 2, 16, 1).next_batch();
        assert_ne!(train, eval);
    }
}
