//! Hand-rolled CLI substrate (clap is not vendorable offline): a small
//! `--flag value` / `--switch` parser plus the `metis` subcommands.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed arguments: positionals + `--key value` flags + `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn req(&self, key: &str) -> Result<String> {
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?} is not an integer: {e}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?} is not a number: {e}")),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

pub const USAGE: &str = "\
metis — FP4/FP8 LLM training via spectral decomposition (paper reproduction)

USAGE:
  metis info      [--artifacts DIR]
      List models, quantization modes and artifacts.
  metis train     --model NAME --mode MODE [--steps N] [--lr F]
                  [--warmup N] [--seed N] [--config FILE] [--downstream]
                  [--checkpoint-every N] [--eval-every N] [--out DIR]
      Train via the AOT train_step artifact; logs runs/<name>/log.jsonl.
  metis eval      [CKPT_DIR] [--fmt mxfp4|nvfp4|fp8|paper_fp4]
                  [--strategy full|rsvd|sparse_sample|random_project]
                  [--rho F] [--max-rank N] [--seed N] [--threads N]
                  [--block-cols N] [--sigma-cap N] [--eval-split DIR]
                  [--batches N] [--batch N] [--layers N] [--d-model N]
                  [--out report.jsonl] [--trace-out trace.json]
                  [--metrics-out metrics.json]
      Native held-out eval harness (no PJRT needed): pack a checkpoint
      dir of .npy weights (or, without CKPT_DIR, the synthetic model)
      through the Eq. 3 split and run a forward-only held-out pass —
      held-out loss + perplexity, per-layer σ-distortion of the packed
      weights vs their high-precision masters, quantized-vs-master
      logit divergence — as one JSONL row, bit-identical for any
      --threads.  Held-out activations come from --eval-split (a dir of
      (b, d) / stacked (N, b, d) .npy batches, matched to layers by
      width d) or from deterministic eval-only probe streams.
  metis eval      --artifact DIR [--seed N] [--threads N] [--batch N]
                  [--batches N] [--sigma-cap N] [--eval-split DIR]
                  [--out report.jsonl] [--trace-out trace.json]
                  [--metrics-out metrics.json]
      Serve the held-out eval from a sealed `metis pack` artifact: the
      packed factors mmap-load with mandatory checksum verification and
      no SVD reruns, so the row lands in milliseconds and is
      bit-identical to `metis eval CKPT` at the manifest's pack seed
      and config.  Format/strategy/rho/max-rank/block-cols come from
      the manifest and cannot be overridden; --seed defaults to the
      pack seed.
  metis eval      --model NAME --mode MODE --ckpt DIR [--downstream]
      Legacy artifact path: held-out loss (+ optional GLUE-like probes)
      for a checkpoint via the AOT eval_step artifact.
  metis analyze   --npy FILE [--k N]
      Spectral report for a weight matrix: spectrum head, elbow fraction,
      participation ratio, Popoviciu bound, quantization impact.
  metis quant     [--fmt mxfp4|nvfp4|fp8] [--rows N] [--cols N]
      Block-quantization bias demo on a synthetic anisotropic matrix.
  metis quantize-model [--ckpt DIR] [--fmt mxfp4|nvfp4|fp8|paper_fp4]
                  [--strategy full|rsvd|sparse_sample|random_project]
                  [--threads N] [--rho F] [--max-rank N] [--seed N]
                  [--layers N] [--d-model N] [--sigma-cap N] [--no-sigma]
                  [--sigma-ref sampled|full] [--block-cols N]
                  [--out report.jsonl] [--trace-out trace.json]
                  [--metrics-out metrics.json]
      Pure-Rust Metis pipeline: sweep a checkpoint dir of .npy weights
      (or, without --ckpt, a synthetic anisotropic model of --layers
      transformer blocks at width --d-model) through the Eq. 3 split +
      Eq. 5 sub-distribution quantization, sharded over --threads
      workers; per-layer error and σ-distortion reports as JSONL.
      Bounded-memory large-layer path: checkpoint payloads stream off
      disk per column block, and layers wider than --block-cols
      (default 1024; 0 = layer granularity) fan out as (layer, block)
      work units, so a 4k²-class matrix neither sits in RAM whole nor
      monopolizes one worker; reports stay bit-identical for any
      thread count.  Layers past --sigma-cap measure σ against the
      §3.1 sampled top-k spectrum (--sigma-ref sampled, the default,
      O(mnk)) instead of skipping; --sigma-ref full keeps the old
      skip-above-cap behavior.
      Decomposition strategies (cost ↓ / accuracy →): full = exact
      Jacobi SVD oracle; rsvd = randomized SVD, 2 power iterations;
      sparse_sample = §3.1 row-sampling sketch + subspace lift
      (< 1e-2 top-k σ error at a fraction of full-SVD cost);
      random_project = zero-iteration sketch, cheapest and loosest.
  metis pack      CKPT_DIR -o DIR [--fmt mxfp4|nvfp4|fp8|paper_fp4]
                  [--strategy full|rsvd|sparse_sample|random_project]
                  [--rho F] [--max-rank N] [--seed N] [--block-cols N]
                  [--threads N] [--trace-out trace.json]
                  [--metrics-out metrics.json]
      Seal a checkpoint dir of .npy weights into a versioned artifact:
      each (layer, column-block) streams through the Eq. 3 split +
      Eq. 5 sub-distribution quantization once (same per-unit pack
      streams as eval/train-native at the same --seed), and the packed
      factors + high-precision masters/spectra land as checksummed
      blobs under DIR/blobs with a canonical-JSON self-checksummed
      manifest.json.  Deterministic byte-for-byte for any --threads.
      Verify offline with tools/validate_artifact.py; serve with
      `metis eval --artifact DIR`.
  metis train-native [--layers N] [--d-model N] [--steps N] [--batch N]
                  [--fmt mxfp4|nvfp4|fp8|paper_fp4]
                  [--strategy full|rsvd|sparse_sample|random_project]
                  [--threads N] [--rho F] [--max-rank N] [--grad-rank N]
                  [--power-iters N] [--lr F] [--warmup N] [--seed N]
                  [--optim sgd|adam] [--repack-every N] [--no-adaptive]
                  [--block-cols N] [--eval-every N] [--eval-split DIR]
                  [--eval-batches N] [--eval-batch N] [--sigma-cap N]
                  [--out steps.jsonl] [--eval-out evals.jsonl]
                  [--trace-out trace.json] [--metrics-out metrics.json]
      Pure-Rust W4A4G4 training loop, no PJRT needed: a synthetic
      anisotropic model is packed once via the Eq. 3 split (quantized
      factors, high-precision S; layers wider than --block-cols pack as
      streamed per-column-block splits), then every step runs quantized
      probe activations forward and the Eq. 6 randomized gradient split
      + §3.2 adaptive spectral LR + sub-distribution quantization
      before the optimizer update, sharded over --threads workers (loss
      curves are bit-identical for any thread count).  Emits one JSON
      object per step on stdout (loss, per-layer σ̃ rescale stats, split
      timings); --out mirrors the stream to a file.
      --eval-every N interleaves held-out eval rows every N steps: the
      fidelity curve of the packed weights (held-out loss/perplexity vs
      the planted targets, per-layer σ-distortion vs the masters, logit
      divergence) over --eval-split batches or deterministic eval-only
      probe streams; --eval-out mirrors the eval rows to a file.
  metis trace summarize DIR
      Offline observability join: read a run's run.json manifest,
      Chrome trace (trace.json), metrics.json snapshot and every
      *.jsonl stream under DIR, and print per-phase wall/CPU
      breakdowns, the top slowest (layer, block) units, and per-stream
      event counts + seq ranges.

Observability: eval / quantize-model / pack / train-native accept
--trace-out FILE and --metrics-out FILE.  Either flag turns on
process-wide span + metric recording (off by default, <= 1% overhead
when on, bit-identical outputs either way).  --trace-out writes a
Chrome trace-event JSON loadable in Perfetto / chrome://tracing with
per-worker rows of pipeline/pack/train/eval unit spans down to
kernel-level GEMM and Jacobi phases; --metrics-out writes a stamped
snapshot of the typed counters (quantizer clip/underflow per format,
GEMM GFLOP/s per shape class, workpool queue depth + helper steals,
reader-cache hit/miss, sigma-distortion running max, packed bytes),
and train-native additionally interleaves a metrics row every 10
steps.  A run.json manifest (run_id, command, seed, config, build
info, stream file list) is written next to the artifacts; every JSONL
row of the run carries the same run_id plus schema_version and a
monotonic seq for offline joining.

Kernel toggles (every subcommand): --qgemm packed|expand selects the
dequant-free packed-operand GEMM path (default: packed — FP4 codes
are contracted natively at ~¼ the operand bytes) or the
unpack-then-matmul oracle (expand); both are bit-identical, so
expand exists for A/B timing and audits.  --simd native|portable
pins the scalar microkernel (portable) instead of the
runtime-detected AVX2/NEON lane (native, the default) — again
bit-identical by construction; the detected lane is recorded in the
run.json manifest (`simd`) and the metrics `kernel` section.

Artifacts default to ./artifacts (built by `make artifacts`);
override with --artifacts or METIS_ARTIFACTS.

Perf trajectory: `cargo bench --bench perf_hotpath` measures the
kernel layer against the preserved pre-kernel implementations (GEMM
GFLOP/s at 64²/256²/1024², Jacobi-256² wall time, fused-vs-naive
quantizer throughput, end-to-end train-native step time) and writes
the paired old/new rows to BENCH_PERF.json at the repo root; CI
uploads it per commit as the `bench-perf` artifact.

Invariant lint: `cargo run -p metis-lint` (or, without cargo,
`python3 tools/lint_invariants.py`) enforces the DESIGN.md §12
catalog over rust/src + rust/tests — deterministic-iteration,
no-narrowing-cast, SAFETY/Ordering discipline, _ref-oracle test
pairing, stamp() event/schema cross-check — with the shared
allowlist at rust/lint/allowlist.txt; `--self-test` runs the
fixture suite.";

pub fn artifacts_flag(args: &Args) -> String {
    args.flags
        .get("artifacts")
        .cloned()
        .or_else(|| std::env::var("METIS_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn flags_and_switches() {
        let a = parse(&["train", "--model", "tiny", "--steps=50", "--downstream"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str("model", ""), "tiny");
        assert_eq!(a.usize("steps", 0).unwrap(), 50);
        assert!(a.switch("downstream"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn missing_and_bad_values() {
        let a = parse(&["--lr", "abc"]);
        assert!(a.f64("lr", 1.0).is_err());
        assert!(a.req("nope").is_err());
        assert_eq!(a.usize("absent", 7).unwrap(), 7);
    }
}
