//! Run store: memoized training runs shared across bench targets.
//!
//! Every bench binary is a separate process and PJRT has no executable
//! serialization in this stack, so recompiling + retraining per table
//! would multiply the wall-clock by the number of reports.  The store
//! keys a finished run by (model, mode, steps, lr, seed) and persists
//! the loss curve, held-out loss, step timing, probe accuracies and the
//! final checkpoint path as JSON under reports/runstore/.  Table benches
//! then *reuse* the training runs the figure benches produced.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::evalharness::eval_downstream;
use crate::coordinator::runlog::RunLog;
use crate::coordinator::{ExperimentConfig, Trainer};
use crate::data::tasks::ALL_TASKS;
use crate::runtime::Engine;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct RunRecord {
    pub model: String,
    pub mode: String,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub test_loss: f32,
    pub step_ms_mean: f64,
    pub compile_ms: f64,
    pub diverged: bool,
    /// task name → eval accuracy (empty unless probes were requested).
    pub probes: BTreeMap<String, f64>,
    pub ckpt_dir: String,
}

impl RunRecord {
    /// See [`crate::coordinator::trainer::final_loss_window`] — NaN for
    /// an empty curve, non-finite tail entries excluded.
    pub fn final_train_loss(&self) -> f32 {
        crate::coordinator::trainer::final_loss_window(&self.losses)
    }

    pub fn avg_probe_acc(&self, tasks: &[&str]) -> f64 {
        let vals: Vec<f64> = tasks
            .iter()
            .filter_map(|t| self.probes.get(*t).copied())
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("mode", Json::str(&self.mode)),
            ("steps", Json::num(self.steps as f64)),
            (
                "losses",
                Json::Arr(self.losses.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            ("test_loss", Json::num(self.test_loss as f64)),
            ("step_ms_mean", Json::num(self.step_ms_mean)),
            ("compile_ms", Json::num(self.compile_ms)),
            ("diverged", Json::Bool(self.diverged)),
            (
                "probes",
                Json::Obj(
                    self.probes
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::num(v)))
                        .collect(),
                ),
            ),
            ("ckpt_dir", Json::str(&self.ckpt_dir)),
        ])
    }

    fn from_json(j: &Json) -> Result<RunRecord> {
        let losses = j
            .req("losses")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_f64()? as f32))
            .collect::<Result<Vec<_>>>()?;
        let mut probes = BTreeMap::new();
        for (k, v) in j.req("probes")?.as_obj()? {
            probes.insert(k.clone(), v.as_f64()?);
        }
        Ok(RunRecord {
            model: j.req("model")?.as_str()?.to_string(),
            mode: j.req("mode")?.as_str()?.to_string(),
            steps: j.req("steps")?.as_usize()?,
            losses,
            test_loss: j.req("test_loss")?.as_f64()? as f32,
            step_ms_mean: j.req("step_ms_mean")?.as_f64()?,
            compile_ms: j.req("compile_ms")?.as_f64()?,
            diverged: j.req("diverged")?.as_bool()?,
            probes,
            ckpt_dir: j.req("ckpt_dir")?.as_str()?.to_string(),
        })
    }
}

pub struct RunStore {
    dir: PathBuf,
}

impl RunStore {
    pub fn open(dir: impl Into<PathBuf>) -> Result<RunStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(RunStore { dir })
    }

    /// Default store under reports/runstore.
    pub fn default_store() -> Result<RunStore> {
        Self::open(crate::bench::reports_dir().join("runstore"))
    }

    fn key(cfg: &ExperimentConfig) -> String {
        format!(
            "{}__{}__s{}__lr{:.0e}__seed{}",
            cfg.model, cfg.mode, cfg.steps, cfg.lr, cfg.seed
        )
    }

    pub fn get(&self, cfg: &ExperimentConfig) -> Option<RunRecord> {
        let path = self.dir.join(format!("{}.json", Self::key(cfg)));
        let text = std::fs::read_to_string(path).ok()?;
        RunRecord::from_json(&Json::parse(&text).ok()?).ok()
    }

    /// Fetch a memoized run or execute it (training + optional probes).
    pub fn get_or_run(
        &self,
        engine: &Engine,
        cfg: &ExperimentConfig,
        with_probes: bool,
    ) -> Result<RunRecord> {
        if let Some(mut rec) = self.get(cfg) {
            if !with_probes || !rec.probes.is_empty() || rec.diverged {
                eprintln!("  [runstore] reuse {}", Self::key(cfg));
                return Ok(rec);
            }
            // Upgrade path: run exists but without probes — evaluate them
            // from the stored checkpoint instead of retraining.
            if std::path::Path::new(&rec.ckpt_dir).is_dir() {
                eprintln!("  [runstore] probe-upgrade {}", Self::key(cfg));
                let pset = engine
                    .manifest
                    .param_set(&format!("{}__{}", cfg.model, cfg.mode))?
                    .clone();
                let params: Vec<crate::runtime::HostValue> = pset
                    .names
                    .iter()
                    .map(|n| {
                        crate::runtime::HostValue::from_npy(&crate::util::npy::read_npy(
                            std::path::Path::new(&rec.ckpt_dir).join(format!("{n}.npy")),
                        )?)
                    })
                    .collect::<Result<_>>()?;
                for r in eval_downstream(
                    engine,
                    &cfg.model,
                    &cfg.mode,
                    &params,
                    cfg.corpus_seed,
                    &ALL_TASKS,
                )? {
                    rec.probes.insert(r.task.paper_name().to_string(), r.accuracy);
                }
                let path = self.dir.join(format!("{}.json", Self::key(cfg)));
                std::fs::write(&path, rec.to_json().to_string())?;
                return Ok(rec);
            }
        }
        eprintln!("  [runstore] train {}", Self::key(cfg));
        let mut trainer = Trainer::new(engine, cfg.clone())?;
        let mut log = RunLog::null();
        let res = trainer.train_with_log(&mut log)?;
        let ckpt = trainer.checkpoint(res.losses.len())?;

        let mut probes = BTreeMap::new();
        if with_probes && !res.diverged {
            for r in eval_downstream(
                engine,
                &cfg.model,
                &cfg.mode,
                trainer.params(),
                cfg.corpus_seed,
                &ALL_TASKS,
            )? {
                probes.insert(r.task.paper_name().to_string(), r.accuracy);
            }
        }
        let rec = RunRecord {
            model: cfg.model.clone(),
            mode: cfg.mode.clone(),
            steps: cfg.steps,
            losses: res.losses,
            test_loss: res.test_loss,
            step_ms_mean: res.step_ms_mean,
            compile_ms: res.compile_ms,
            diverged: res.diverged,
            probes,
            ckpt_dir: ckpt.to_string_lossy().into_owned(),
        };
        let path = self.dir.join(format!("{}.json", Self::key(cfg)));
        std::fs::write(&path, rec.to_json().to_string())
            .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
        Ok(rec)
    }
}

/// Canonical bench run length per model (shared by every bench target so
/// run-store keys coincide and runs are reused across processes).
pub fn canonical_steps(model: &str) -> usize {
    match model {
        "nano" => 100,
        "tiny" => 150,
        "small" => 220,
        _ => 200,
    }
}

/// Canonical peak lr for the FP8 comparison benches: at the "small"
/// scale the hottest phase of the 1e-2 schedule sits exactly on the
/// stability edge — FP32 survives, FP8 noise tips it over (all FP8
/// variants NaN'd near loss ≈ 3.1).  The FP8 experiments therefore run
/// their *entire* mode set (incl. the FP32 baseline) at 5e-3 so the
/// comparison stays fair.  See EXPERIMENTS.md §Fig. 6.
pub const FP8_BENCH_LR: f64 = 5e-3;

/// The bench suite's canonical experiment configs.
pub fn bench_config(model: &str, mode: &str, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench".into(),
        model: model.into(),
        mode: mode.into(),
        steps,
        lr: 1e-2,
        warmup: (steps / 10).max(5),
        checkpoint_every: (steps / 4).max(1),
        out_dir: crate::bench::reports_dir()
            .join("runs")
            .to_string_lossy()
            .into_owned(),
        ..ExperimentConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_roundtrip() {
        let rec = RunRecord {
            model: "tiny".into(),
            mode: "nvfp4_metis".into(),
            steps: 10,
            losses: vec![5.0, 4.0, 3.5],
            test_loss: 3.4,
            step_ms_mean: 61.5,
            compile_ms: 80_000.0,
            diverged: false,
            probes: [("CoLA".to_string(), 0.68)].into_iter().collect(),
            ckpt_dir: "/tmp/x".into(),
        };
        let j = rec.to_json().to_string();
        let back = RunRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.losses, rec.losses);
        assert_eq!(back.probes["CoLA"], 0.68);
        assert!(!back.diverged);
        assert!((back.final_train_loss() - 4.166_666_7).abs() < 1e-4);
    }

    #[test]
    fn record_final_loss_skips_nan_tail() {
        // Regression: RunRecord used to duplicate the pre-fix logic —
        // 0.0 for an empty curve, NaN tail averaged in (the fig6/fig7
        // benches consume this copy on diverged runs).
        let mut rec = RunRecord {
            model: "t".into(),
            mode: "m".into(),
            steps: 3,
            losses: vec![4.0, 2.0, f32::NAN],
            test_loss: f32::NAN,
            step_ms_mean: 1.0,
            compile_ms: 0.0,
            diverged: true,
            probes: BTreeMap::new(),
            ckpt_dir: String::new(),
        };
        assert!((rec.final_train_loss() - 3.0).abs() < 1e-6);
        rec.losses.clear();
        assert!(rec.final_train_loss().is_nan());
    }

    #[test]
    fn avg_probe_handles_missing() {
        let rec = RunRecord {
            model: "t".into(),
            mode: "m".into(),
            steps: 1,
            losses: vec![1.0],
            test_loss: 1.0,
            step_ms_mean: 1.0,
            compile_ms: 0.0,
            diverged: false,
            probes: [("A".to_string(), 0.5), ("B".to_string(), 0.7)]
                .into_iter()
                .collect(),
            ckpt_dir: String::new(),
        };
        assert!((rec.avg_probe_acc(&["A", "B"]) - 0.6).abs() < 1e-12);
        assert!(rec.avg_probe_acc(&["missing"]).is_nan());
    }
}
