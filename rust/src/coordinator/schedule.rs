//! Learning-rate schedule (paper §4.1: linear warmup → cosine decay).
//!
//! Owned by the coordinator — the `train_step` artifact takes `lr` as a
//! runtime input, so one artifact serves any run length or policy.

#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub peak: f64,
    pub warmup: usize,
    pub total: usize,
}

impl Schedule {
    pub fn new(peak: f64, warmup: usize, total: usize) -> Self {
        Self {
            peak,
            warmup,
            total,
        }
    }

    pub fn lr_at(&self, step: usize) -> f64 {
        let s = step as f64;
        if step < self.warmup {
            return self.peak * s / self.warmup.max(1) as f64;
        }
        let span = (self.total.saturating_sub(self.warmup)).max(1) as f64;
        let prog = ((s - self.warmup as f64) / span).clamp(0.0, 1.0);
        self.peak * 0.5 * (1.0 + (std::f64::consts::PI * prog).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_cosine() {
        let s = Schedule::new(1.0, 10, 110);
        assert_eq!(s.lr_at(0), 0.0);
        assert!((s.lr_at(5) - 0.5).abs() < 1e-12);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-12);
        assert!(s.lr_at(60) < 1.0);
        assert!(s.lr_at(110) < 1e-9);
        // monotone decreasing after warmup
        let mut prev = s.lr_at(10);
        for t in 11..=110 {
            let cur = s.lr_at(t);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn matches_python_lr_at() {
        // python model.lr_at(OptConfig(lr=1.0, warmup=10, total_steps=110))
        // spot values (see test_model.py::test_lr_schedule).
        let s = Schedule::new(1.0, 10, 110);
        assert!((s.lr_at(5) - 0.5).abs() < 1e-9);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-9);
    }
}
