//! The training orchestrator: owns the engine state for one run —
//! parameter/optimizer buffers, a prefetching data-loader thread, the
//! step loop feeding the `train_step` artifact, periodic held-out
//! evaluation, checkpointing, and the JSONL run log.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::runlog::RunLog;
use crate::coordinator::schedule::Schedule;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::BatchIterator;
use crate::runtime::{Engine, HostValue};
use crate::util::json::Json;
use crate::util::npy;
use crate::util::timer::{Stats, Stopwatch};

/// Split ids for the deterministic data streams.
pub const SPLIT_TRAIN: u64 = 0;
pub const SPLIT_EVAL: u64 = 1;

#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub mode: String,
    pub model: String,
    pub losses: Vec<f32>,
    pub gnorms: Vec<f32>,
    pub test_loss: f32,
    pub step_ms_mean: f64,
    pub step_ms_p95: f64,
    pub compile_ms: f64,
    pub diverged: bool,
}

impl RunResult {
    pub fn final_train_loss(&self) -> f32 {
        let tail = self.losses.len().saturating_sub(10);
        let window = &self.losses[tail..];
        window.iter().sum::<f32>() / window.len().max(1) as f32
    }
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ExperimentConfig,
    /// Flat state vector: params ++ m ++ v (manifest order).
    pub state: Vec<HostValue>,
    pub n_params: usize,
    pub param_names: Vec<String>,
    artifact: String,
    eval_artifact: String,
    batch: usize,
    seq_len: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: ExperimentConfig) -> Result<Trainer<'e>> {
        let batch = 8; // all artifacts are exported at b8 (manifest)
        let artifact = engine
            .manifest
            .name_for("train_step", &cfg.model, &cfg.mode, batch);
        let eval_artifact = engine
            .manifest
            .name_for("eval_loss", &cfg.model, &cfg.mode, batch);
        let spec = engine
            .manifest
            .artifact(&artifact)
            .with_context(|| format!("no train_step artifact for {}/{}", cfg.model, cfg.mode))?;
        let params_key = spec
            .params_key
            .clone()
            .ok_or_else(|| anyhow!("artifact {artifact} lacks params_key"))?;
        let params = engine.load_params(&params_key)?;
        let n_params = params.len();
        let param_names = engine.manifest.param_set(&params_key)?.names.clone();

        let zeros: Vec<HostValue> = params
            .iter()
            .map(|p| HostValue::F32 {
                shape: p.shape().to_vec(),
                data: vec![0.0; p.shape().iter().product()],
            })
            .collect();
        let mut state = params;
        state.extend(zeros.iter().cloned());
        state.extend(zeros);

        let seq_len = engine
            .manifest
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("unknown model {}", cfg.model))?
            .seq_len;

        Ok(Trainer {
            engine,
            cfg,
            state,
            n_params,
            param_names,
            artifact,
            eval_artifact,
            batch,
            seq_len,
        })
    }

    /// Spawn the prefetching loader thread: deterministic batches pushed
    /// through a bounded channel (backpressure = channel depth).
    fn spawn_loader(&self, steps: usize) -> mpsc::Receiver<Vec<i32>> {
        let (tx, rx) = mpsc::sync_channel(self.cfg.prefetch);
        let corpus_cfg = CorpusConfig::new(
            self.engine.manifest.models[&self.cfg.model].vocab,
            self.cfg.corpus_seed,
        );
        let (batch, seq_len) = (self.batch, self.seq_len);
        thread::spawn(move || {
            let corpus = Corpus::new(corpus_cfg);
            let mut it = BatchIterator::new(&corpus, batch, seq_len, SPLIT_TRAIN);
            for _ in 0..steps {
                if tx.send(it.next_batch()).is_err() {
                    break; // trainer dropped the receiver
                }
            }
        });
        rx
    }

    /// Run the configured number of steps; returns the loss curve.
    pub fn train(&mut self) -> Result<RunResult> {
        let run_dir = self.cfg.run_dir();
        let mut log = RunLog::create(&run_dir, false)?;
        log.event(
            "config",
            vec![
                ("model", Json::str(&self.cfg.model)),
                ("mode", Json::str(&self.cfg.mode)),
                ("steps", Json::num(self.cfg.steps as f64)),
                ("lr", Json::num(self.cfg.lr)),
                ("seed", Json::num(self.cfg.seed as f64)),
            ],
        );
        self.train_with_log(&mut log)
    }

    /// Train quietly (benches supply RunLog::null()).
    pub fn train_with_log(&mut self, log: &mut RunLog) -> Result<RunResult> {
        let sched = Schedule::new(self.cfg.lr, self.cfg.warmup, self.cfg.steps);
        let rx = self.spawn_loader(self.cfg.steps);

        // First execution includes XLA compilation; measure it separately.
        let compile_watch = Stopwatch::start();
        self.engine.load(&self.artifact)?;
        let compile_ms = compile_watch.ms();

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut gnorms = Vec::with_capacity(self.cfg.steps);
        let mut step_stats = Stats::default();
        let mut diverged = false;

        for step in 0..self.cfg.steps {
            let tokens = rx
                .recv()
                .map_err(|_| anyhow!("data loader thread died"))?;
            let lr = sched.lr_at(step);
            let watch = Stopwatch::start();

            let tok_hv = HostValue::I32 {
                shape: vec![self.batch, self.seq_len + 1],
                data: tokens,
            };
            let step_hv = HostValue::scalar_i32(step as i32);
            let seed_hv = HostValue::scalar_i32(self.cfg.seed as i32);
            let lr_hv = HostValue::scalar_f32(lr as f32);
            let mut inputs: Vec<&HostValue> = self.state.iter().collect();
            inputs.push(&tok_hv);
            inputs.push(&step_hv);
            inputs.push(&seed_hv);
            inputs.push(&lr_hv);

            let outs = self.engine.run(&self.artifact, &inputs)?;
            let n3 = 3 * self.n_params;
            let loss = outs[n3].scalar()?;
            let gnorm = outs[n3 + 1].scalar()?;
            self.state = outs;
            self.state.truncate(n3);

            let ms = watch.ms();
            if step > 0 {
                step_stats.add(ms); // step 0 may still hit lazy costs
            }
            losses.push(loss);
            gnorms.push(gnorm);
            log.step(step, loss, gnorm, lr, ms);

            if !loss.is_finite() {
                diverged = true;
                log.event("diverged", vec![("step", Json::num(step as f64))]);
                break;
            }
            if self.cfg.checkpoint_every > 0
                && step > 0
                && step % self.cfg.checkpoint_every == 0
            {
                self.checkpoint(step)?;
            }
            if self.cfg.eval_every > 0 && step > 0 && step % self.cfg.eval_every == 0 {
                let el = self.eval_loss(self.cfg.eval_batches)?;
                log.event(
                    "eval",
                    vec![
                        ("step", Json::num(step as f64)),
                        ("test_loss", Json::num(el as f64)),
                    ],
                );
            }
        }

        let test_loss = if diverged {
            f32::NAN
        } else {
            self.eval_loss(self.cfg.eval_batches)?
        };
        log.event(
            "done",
            vec![
                ("test_loss", Json::num(test_loss as f64)),
                ("steps", Json::num(losses.len() as f64)),
            ],
        );

        Ok(RunResult {
            name: self.cfg.name.clone(),
            mode: self.cfg.mode.clone(),
            model: self.cfg.model.clone(),
            losses,
            gnorms,
            test_loss,
            step_ms_mean: step_stats.mean(),
            step_ms_p95: step_stats.percentile(95.0),
            compile_ms,
            diverged,
        })
    }

    /// Current parameters (first n_params state entries).
    pub fn params(&self) -> &[HostValue] {
        &self.state[..self.n_params]
    }

    /// Held-out loss averaged over `n` deterministic eval batches.
    pub fn eval_loss(&self, n: usize) -> Result<f32> {
        let corpus = Corpus::new(CorpusConfig::new(
            self.engine.manifest.models[&self.cfg.model].vocab,
            self.cfg.corpus_seed,
        ));
        let it = BatchIterator::new(&corpus, self.batch, self.seq_len, SPLIT_EVAL);
        let mut total = 0.0f64;
        for i in 0..n {
            let tokens = it.batch_at(i as u64);
            let tok_hv = HostValue::I32 {
                shape: vec![self.batch, self.seq_len + 1],
                data: tokens,
            };
            let mut inputs: Vec<&HostValue> = self.params().iter().collect();
            inputs.push(&tok_hv);
            let outs = self.engine.run(&self.eval_artifact, &inputs)?;
            total += outs[0].scalar()? as f64;
        }
        Ok((total / n as f64) as f32)
    }

    /// Write current params as npy blobs under run_dir/ckpt_<step>/.
    pub fn checkpoint(&self, step: usize) -> Result<std::path::PathBuf> {
        let dir = self.cfg.run_dir().join(format!("ckpt_{step:06}"));
        std::fs::create_dir_all(&dir)?;
        for (name, hv) in self.param_names.iter().zip(self.params()) {
            npy::write_npy(dir.join(format!("{name}.npy")), &hv.to_npy())?;
        }
        Ok(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_result_final_loss_window() {
        let r = RunResult {
            name: "x".into(),
            mode: "fp32".into(),
            model: "nano".into(),
            losses: (0..20).map(|i| 20.0 - i as f32).collect(),
            gnorms: vec![],
            test_loss: 1.0,
            step_ms_mean: 0.0,
            step_ms_p95: 0.0,
            compile_ms: 0.0,
            diverged: false,
        };
        // mean of last 10 losses: 10..1 → 5.5
        assert!((r.final_train_loss() - 5.5).abs() < 1e-6);
    }
}
