//! The training orchestrator: owns the engine state for one run —
//! parameter/optimizer buffers, a prefetching data-loader thread, the
//! step loop feeding the `train_step` artifact, periodic held-out
//! evaluation, checkpointing, and the JSONL run log.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::runlog::RunLog;
use crate::coordinator::schedule::Schedule;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::BatchIterator;
use crate::metis::trainstate::{GradStepConfig, Optim, TrainState};
use crate::metis::{LayerSpec, MetisQuantConfig};
use crate::runtime::{Engine, HostValue};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::npy;
use crate::util::timer::{Stats, Stopwatch};

/// Split ids for the deterministic data streams.
pub const SPLIT_TRAIN: u64 = 0;
pub const SPLIT_EVAL: u64 = 1;

#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub mode: String,
    pub model: String,
    pub losses: Vec<f32>,
    pub gnorms: Vec<f32>,
    pub test_loss: f32,
    pub step_ms_mean: f64,
    pub step_ms_p95: f64,
    pub compile_ms: f64,
    pub diverged: bool,
}

/// Mean of the finite entries in a loss curve's last-10-step window;
/// NaN when the curve is empty or the whole window is non-finite.
/// (The old per-type copies reported 0.0 for an empty curve and
/// averaged the NaN tail a diverged run leaves behind.)  Shared by
/// `RunResult` and `runstore::RunRecord`.
pub fn final_loss_window(losses: &[f32]) -> f32 {
    let tail = losses.len().saturating_sub(10);
    let (sum, n) = losses[tail..]
        .iter()
        .filter(|x| x.is_finite())
        .fold((0.0f32, 0usize), |(s, c), &x| (s + x, c + 1));
    if n == 0 {
        f32::NAN
    } else {
        sum / n as f32
    }
}

impl RunResult {
    /// See [`final_loss_window`].
    pub fn final_train_loss(&self) -> f32 {
        final_loss_window(&self.losses)
    }
}

/// Lossless bridge from the u64 experiment seed to the `train_step`
/// artifact's scalar s32 input.  Seeds ≥ 2³¹ used to wrap negative via
/// `as i32` and silently diverge from the Python-side stream; they are
/// a hard error until the exported graph grows a split hi/lo seed.
pub fn seed_input(seed: u64) -> Result<HostValue> {
    let s = i32::try_from(seed).map_err(|_| {
        anyhow!(
            "experiment seed {seed} exceeds the train_step artifact's i32 seed \
             input; use a seed < 2^31 or re-export the graph with a hi/lo seed pair"
        )
    })?;
    Ok(HostValue::scalar_i32(s))
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ExperimentConfig,
    /// Flat state vector: params ++ m ++ v (manifest order).
    pub state: Vec<HostValue>,
    pub n_params: usize,
    pub param_names: Vec<String>,
    artifact: String,
    eval_artifact: String,
    batch: usize,
    seq_len: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: ExperimentConfig) -> Result<Trainer<'e>> {
        let batch = 8; // all artifacts are exported at b8 (manifest)
        let artifact = engine
            .manifest
            .name_for("train_step", &cfg.model, &cfg.mode, batch);
        let eval_artifact = engine
            .manifest
            .name_for("eval_loss", &cfg.model, &cfg.mode, batch);
        let spec = engine
            .manifest
            .artifact(&artifact)
            .with_context(|| format!("no train_step artifact for {}/{}", cfg.model, cfg.mode))?;
        let params_key = spec
            .params_key
            .clone()
            .ok_or_else(|| anyhow!("artifact {artifact} lacks params_key"))?;
        seed_input(cfg.seed)?; // fail at construction, not mid-run
        let params = engine.load_params(&params_key)?;
        let n_params = params.len();
        let param_names = engine.manifest.param_set(&params_key)?.names.clone();

        let zeros: Vec<HostValue> = params
            .iter()
            .map(|p| HostValue::F32 {
                shape: p.shape().to_vec(),
                data: vec![0.0; p.shape().iter().product()],
            })
            .collect();
        let mut state = params;
        state.extend(zeros.iter().cloned());
        state.extend(zeros);

        let seq_len = engine
            .manifest
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("unknown model {}", cfg.model))?
            .seq_len;

        Ok(Trainer {
            engine,
            cfg,
            state,
            n_params,
            param_names,
            artifact,
            eval_artifact,
            batch,
            seq_len,
        })
    }

    /// Spawn the prefetching loader thread: deterministic batches pushed
    /// through a bounded channel (backpressure = channel depth).
    fn spawn_loader(&self, steps: usize) -> mpsc::Receiver<Vec<i32>> {
        let (tx, rx) = mpsc::sync_channel(self.cfg.prefetch);
        let corpus_cfg = CorpusConfig::new(
            self.engine.manifest.models[&self.cfg.model].vocab,
            self.cfg.corpus_seed,
        );
        let (batch, seq_len) = (self.batch, self.seq_len);
        thread::spawn(move || {
            let corpus = Corpus::new(corpus_cfg);
            let mut it = BatchIterator::new(&corpus, batch, seq_len, SPLIT_TRAIN);
            for _ in 0..steps {
                if tx.send(it.next_batch()).is_err() {
                    break; // trainer dropped the receiver
                }
            }
        });
        rx
    }

    /// Run the configured number of steps; returns the loss curve.
    pub fn train(&mut self) -> Result<RunResult> {
        let run_dir = self.cfg.run_dir();
        let mut log = RunLog::create(&run_dir, false)?;
        log.event(
            "config",
            vec![
                ("model", Json::str(&self.cfg.model)),
                ("mode", Json::str(&self.cfg.mode)),
                ("steps", Json::num(self.cfg.steps as f64)),
                ("lr", Json::num(self.cfg.lr)),
                ("seed", Json::num(self.cfg.seed as f64)),
            ],
        );
        self.train_with_log(&mut log)
    }

    /// Train quietly (benches supply RunLog::null()).
    pub fn train_with_log(&mut self, log: &mut RunLog) -> Result<RunResult> {
        let sched = Schedule::new(self.cfg.lr, self.cfg.warmup, self.cfg.steps);
        let seed_hv = seed_input(self.cfg.seed)?;
        let rx = self.spawn_loader(self.cfg.steps);

        // First execution includes XLA compilation; measure it separately.
        let compile_watch = Stopwatch::start();
        self.engine.load(&self.artifact)?;
        let compile_ms = compile_watch.ms();

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut gnorms = Vec::with_capacity(self.cfg.steps);
        let mut step_stats = Stats::default();
        let mut diverged = false;

        for step in 0..self.cfg.steps {
            let tokens = rx
                .recv()
                .map_err(|_| anyhow!("data loader thread died"))?;
            let lr = sched.lr_at(step);
            let watch = Stopwatch::start();

            let tok_hv = HostValue::I32 {
                shape: vec![self.batch, self.seq_len + 1],
                data: tokens,
            };
            let step_hv = HostValue::scalar_i32(
                i32::try_from(step)
                    .map_err(|_| anyhow!("step counter {step} exceeds i32::MAX"))?,
            );
            let lr_hv = HostValue::scalar_f32(lr as f32);
            let mut inputs: Vec<&HostValue> = self.state.iter().collect();
            inputs.push(&tok_hv);
            inputs.push(&step_hv);
            inputs.push(&seed_hv);
            inputs.push(&lr_hv);

            let outs = self.engine.run(&self.artifact, &inputs)?;
            let n3 = 3 * self.n_params;
            let loss = outs[n3].scalar()?;
            let gnorm = outs[n3 + 1].scalar()?;
            self.state = outs;
            self.state.truncate(n3);

            let ms = watch.ms();
            if step > 0 {
                step_stats.add(ms); // step 0 may still hit lazy costs
            }
            losses.push(loss);
            gnorms.push(gnorm);
            log.step(step, loss, gnorm, lr, ms);

            if !loss.is_finite() {
                diverged = true;
                log.event("diverged", vec![("step", Json::num(step as f64))]);
                break;
            }
            if self.cfg.checkpoint_every > 0
                && step > 0
                && step % self.cfg.checkpoint_every == 0
            {
                self.checkpoint(step)?;
            }
            if self.cfg.eval_every > 0 && step > 0 && step % self.cfg.eval_every == 0 {
                let el = self.eval_loss(self.cfg.eval_batches)?;
                log.event(
                    "eval",
                    vec![
                        ("step", Json::num(step as f64)),
                        ("test_loss", Json::num(el as f64)),
                    ],
                );
            }
        }

        let test_loss = if diverged {
            f32::NAN
        } else {
            self.eval_loss(self.cfg.eval_batches)?
        };
        log.event(
            "done",
            vec![
                ("test_loss", Json::num(test_loss as f64)),
                ("steps", Json::num(losses.len() as f64)),
            ],
        );

        Ok(RunResult {
            name: self.cfg.name.clone(),
            mode: self.cfg.mode.clone(),
            model: self.cfg.model.clone(),
            losses,
            gnorms,
            test_loss,
            step_ms_mean: step_stats.mean(),
            step_ms_p95: step_stats.percentile(95.0),
            compile_ms,
            diverged,
        })
    }

    /// Current parameters (first n_params state entries).
    pub fn params(&self) -> &[HostValue] {
        &self.state[..self.n_params]
    }

    /// Init-time Eq. 3 packing of the trainer's weight matrices into
    /// the native Metis train state — the hook through which the
    /// `GradStep`-driven step loop (`metis::trainstate`) takes over the
    /// PJRT path: once artifacts expose per-parameter gradients, the
    /// same `TrainState::step_with` that powers `metis train-native`
    /// runs here with real gradients instead of the synthetic probe
    /// objective.  2-D parameters pack one layer each; JAX-stacked
    /// `(L, m, n)` parameters unstack into L layers (the same layout
    /// `load_checkpoint_dir` handles).  Vectors/scalars (biases, norms)
    /// stay full-precision in the flat state vector and are skipped.
    ///
    /// Packing goes through the streamed `LayerSpec` path: wide layers
    /// split into `block_cols`-column packing blocks fanned across
    /// `threads` workers, so paper-scale parameter sets never
    /// materialize whole-matrix split workspaces at init.
    pub fn pack_weights(
        &self,
        quant: &MetisQuantConfig,
        grad: GradStepConfig,
        optim: Optim,
        block_cols: usize,
        threads: usize,
    ) -> Result<TrainState> {
        let mut specs: Vec<LayerSpec> = Vec::new();
        for (name, hv) in self.param_names.iter().zip(self.params()) {
            let (shape, data) = match hv {
                HostValue::F32 { shape, data } => (shape, data),
                HostValue::I32 { .. } => continue,
            };
            match shape[..] {
                [m, n] if m >= 2 && n >= 2 => {
                    specs.push(LayerSpec::mem(name.clone(), Matrix::from_f32(m, n, data)));
                }
                [stack, m, n] if m >= 2 && n >= 2 => {
                    for l in 0..stack {
                        specs.push(LayerSpec::mem(
                            format!("{name}.{l}"),
                            Matrix::from_f32(m, n, &data[l * m * n..(l + 1) * m * n]),
                        ));
                    }
                }
                _ => {}
            }
        }
        TrainState::init_specs(
            specs,
            *quant,
            grad,
            optim,
            self.cfg.seed,
            block_cols,
            threads,
        )
    }

    /// Held-out loss averaged over `n` deterministic eval batches.
    pub fn eval_loss(&self, n: usize) -> Result<f32> {
        let corpus = Corpus::new(CorpusConfig::new(
            self.engine.manifest.models[&self.cfg.model].vocab,
            self.cfg.corpus_seed,
        ));
        let it = BatchIterator::new(&corpus, self.batch, self.seq_len, SPLIT_EVAL);
        let mut total = 0.0f64;
        for i in 0..n {
            let tokens = it.batch_at(i as u64);
            let tok_hv = HostValue::I32 {
                shape: vec![self.batch, self.seq_len + 1],
                data: tokens,
            };
            let mut inputs: Vec<&HostValue> = self.params().iter().collect();
            inputs.push(&tok_hv);
            let outs = self.engine.run(&self.eval_artifact, &inputs)?;
            total += outs[0].scalar()? as f64;
        }
        Ok((total / n as f64) as f32)
    }

    /// Write current params as npy blobs under run_dir/ckpt_<step>/.
    pub fn checkpoint(&self, step: usize) -> Result<std::path::PathBuf> {
        let dir = self.cfg.run_dir().join(format!("ckpt_{step:06}"));
        std::fs::create_dir_all(&dir)?;
        for (name, hv) in self.param_names.iter().zip(self.params()) {
            npy::write_npy(dir.join(format!("{name}.npy")), &hv.to_npy())?;
        }
        Ok(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_result_final_loss_window() {
        let r = RunResult {
            name: "x".into(),
            mode: "fp32".into(),
            model: "nano".into(),
            losses: (0..20).map(|i| 20.0 - i as f32).collect(),
            gnorms: vec![],
            test_loss: 1.0,
            step_ms_mean: 0.0,
            step_ms_p95: 0.0,
            compile_ms: 0.0,
            diverged: false,
        };
        // mean of last 10 losses: 10..1 → 5.5
        assert!((r.final_train_loss() - 5.5).abs() < 1e-6);
    }

    fn result_with_losses(losses: Vec<f32>) -> RunResult {
        RunResult {
            name: "x".into(),
            mode: "fp32".into(),
            model: "nano".into(),
            losses,
            gnorms: vec![],
            test_loss: 1.0,
            step_ms_mean: 0.0,
            step_ms_p95: 0.0,
            compile_ms: 0.0,
            diverged: false,
        }
    }

    #[test]
    fn final_loss_is_nan_for_empty_curve() {
        // Regression: an empty curve used to report 0.0 — indistinguishable
        // from a perfectly-converged run.
        assert!(result_with_losses(vec![]).final_train_loss().is_nan());
    }

    #[test]
    fn final_loss_excludes_non_finite_tail() {
        // Regression: a diverged run's NaN tail used to poison the mean.
        let mut losses: Vec<f32> = (0..12).map(|i| 12.0 - i as f32).collect();
        losses.push(f32::NAN); // divergence at the end
        let r = result_with_losses(losses);
        // Window = last 10 entries [9..1, NaN]; finite mean = (9+..+1)/9 = 5.
        assert!((r.final_train_loss() - 5.0).abs() < 1e-6);
        // All-NaN window → NaN, not a number invented from nothing.
        let r = result_with_losses(vec![f32::NAN, f32::INFINITY]);
        assert!(r.final_train_loss().is_nan());
    }

    #[test]
    fn seed_input_is_lossless_or_loud() {
        // Regression: seeds ≥ 2³¹ wrapped negative via `as i32`, silently
        // decoupling the graph-side PRNG stream from the config.
        let hv = seed_input(7).unwrap();
        assert_eq!(hv.i32s().unwrap(), &[7]);
        let hv = seed_input(i32::MAX as u64).unwrap();
        assert_eq!(hv.i32s().unwrap(), &[i32::MAX]);
        for bad in [1u64 << 31, u64::MAX, (i32::MAX as u64) + 1] {
            let err = seed_input(bad).unwrap_err().to_string();
            assert!(err.contains("seed"), "unhelpful error: {err}");
        }
    }
}
