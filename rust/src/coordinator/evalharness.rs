//! Downstream evaluation harness: frozen features → linear probes over
//! the six GLUE-shaped tasks (Tables 1–3 and 5).

use anyhow::{anyhow, Result};

use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::tasks::{Task, TaskKind, ALL_TASKS};
use crate::probe::{Probe, ProbeConfig};
use crate::runtime::{Engine, HostValue};

#[derive(Clone, Debug)]
pub struct DownstreamResult {
    pub task: TaskKind,
    pub accuracy: f64,
    pub n_eval: usize,
}

/// Extract features for a set of examples through the `features`
/// artifact (fixed batch size — remainder padded then truncated).
pub fn extract_features(
    engine: &Engine,
    artifact: &str,
    params: &[HostValue],
    examples: &[Vec<i32>],
    batch: usize,
    seq_len: usize,
) -> Result<Vec<f32>> {
    let spec = engine.manifest.artifact(artifact)?;
    let d_out = spec
        .model
        .as_ref()
        .and_then(|m| engine.manifest.models.get(m))
        .map(|m| m.d_model)
        .ok_or_else(|| anyhow!("features artifact lacks model info"))?;

    let mut feats = Vec::with_capacity(examples.len() * d_out);
    let mut i = 0;
    while i < examples.len() {
        let mut toks = Vec::with_capacity(batch * seq_len);
        let mut real = 0;
        for b in 0..batch {
            if i + b < examples.len() {
                assert_eq!(examples[i + b].len(), seq_len);
                toks.extend(&examples[i + b]);
                real += 1;
            } else {
                toks.extend(std::iter::repeat(0).take(seq_len));
            }
        }
        let tok_hv = HostValue::I32 {
            shape: vec![batch, seq_len],
            data: toks,
        };
        let mut inputs: Vec<&HostValue> = params.iter().collect();
        inputs.push(&tok_hv);
        let outs = engine.run(artifact, &inputs)?;
        let f = outs[0].f32s()?;
        feats.extend_from_slice(&f[..real * d_out]);
        i += real;
    }
    Ok(feats)
}

/// Probe one task on frozen features of the given trained params.
pub fn eval_task(
    engine: &Engine,
    features_artifact: &str,
    params: &[HostValue],
    task: &Task,
    batch: usize,
) -> Result<DownstreamResult> {
    let model_name = engine
        .manifest
        .artifact(features_artifact)?
        .model
        .clone()
        .unwrap();
    let dim = engine.manifest.models[&model_name].d_model;

    let train_toks: Vec<Vec<i32>> = task.train.iter().map(|e| e.tokens.clone()).collect();
    let eval_toks: Vec<Vec<i32>> = task.eval.iter().map(|e| e.tokens.clone()).collect();
    let train_labels: Vec<usize> = task.train.iter().map(|e| e.label).collect();
    let eval_labels: Vec<usize> = task.eval.iter().map(|e| e.label).collect();

    let ftr = extract_features(engine, features_artifact, params, &train_toks, batch, task.seq_len)?;
    let fev = extract_features(engine, features_artifact, params, &eval_toks, batch, task.seq_len)?;

    let (probe, norm) = Probe::train(
        &ftr,
        &train_labels,
        dim,
        task.kind.n_classes(),
        &ProbeConfig::default(),
    );
    let accuracy = probe.accuracy(&norm, &fev, &eval_labels);
    Ok(DownstreamResult {
        task: task.kind,
        accuracy,
        n_eval: eval_labels.len(),
    })
}

/// Full downstream sweep (all six tasks) for one trained model.
pub fn eval_downstream(
    engine: &Engine,
    model: &str,
    mode: &str,
    params: &[HostValue],
    corpus_seed: u64,
    tasks: &[TaskKind],
) -> Result<Vec<DownstreamResult>> {
    let batch = 8;
    let artifact = engine.manifest.name_for("features", model, mode, batch);
    let info = &engine.manifest.models[model];
    let corpus = Corpus::new(CorpusConfig::new(info.vocab, corpus_seed));
    let mut out = Vec::new();
    for kind in tasks.iter().copied().filter(|k| ALL_TASKS.contains(k)) {
        let task = Task::generate(&corpus, kind, info.seq_len, 0);
        out.push(eval_task(engine, &artifact, params, &task, batch)?);
    }
    Ok(out)
}
