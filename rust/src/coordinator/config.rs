//! Experiment configuration: defaults ← TOML file ← CLI overrides.

use anyhow::{bail, Result};

use crate::util::toml::TomlDoc;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Model config name from the manifest ("nano" | "tiny" | "small").
    pub model: String,
    /// Quantization mode ("fp32", "nvfp4_metis", ... see manifest.modes).
    pub mode: String,
    pub steps: usize,
    pub seed: u64,
    /// Peak learning rate + schedule (owned by the coordinator).
    pub lr: f64,
    pub warmup: usize,
    /// Evaluate held-out loss every N steps (0 = only at the end).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Checkpoint params every N steps (0 = only final).
    pub checkpoint_every: usize,
    pub out_dir: String,
    pub corpus_seed: u64,
    /// Bounded prefetch depth of the data-loader channel.
    pub prefetch: usize,
    /// Run downstream probes after training.
    pub downstream: bool,
    /// Artifact directory.
    pub artifacts: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            model: "tiny".into(),
            mode: "fp32".into(),
            steps: 200,
            seed: 0,
            lr: 1e-2,
            warmup: 20,
            eval_every: 0,
            eval_batches: 8,
            checkpoint_every: 0,
            out_dir: "runs".into(),
            corpus_seed: 7,
            prefetch: 4,
            downstream: false,
            artifacts: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            name: doc.str_or("name", &d.name),
            model: doc.str_or("train.model", &d.model),
            mode: doc.str_or("train.mode", &d.mode),
            steps: doc.i64_or("train.steps", d.steps as i64) as usize,
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            lr: doc.f64_or("train.lr", d.lr),
            warmup: doc.i64_or("train.warmup", d.warmup as i64) as usize,
            eval_every: doc.i64_or("eval.every", d.eval_every as i64) as usize,
            eval_batches: doc.i64_or("eval.batches", d.eval_batches as i64) as usize,
            checkpoint_every: doc.i64_or("train.checkpoint_every", 0) as usize,
            out_dir: doc.str_or("out.dir", &d.out_dir),
            corpus_seed: doc.i64_or("data.seed", d.corpus_seed as i64) as u64,
            prefetch: doc.i64_or("data.prefetch", d.prefetch as i64) as usize,
            downstream: doc.bool_or("eval.downstream", d.downstream),
            artifacts: doc.str_or("artifacts.dir", &d.artifacts),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        Self::from_toml(&TomlDoc::load(path)?)
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("train.steps must be > 0");
        }
        if self.lr <= 0.0 {
            bail!("train.lr must be > 0");
        }
        if self.prefetch == 0 {
            bail!("data.prefetch must be > 0");
        }
        Ok(())
    }

    pub fn run_dir(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.out_dir)
            .join(format!("{}__{}__{}", self.name, self.model, self.mode))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_toml_overrides_defaults() {
        let doc = TomlDoc::parse(
            "name = \"x\"\n[train]\nmodel = \"small\"\nmode = \"nvfp4_metis\"\nsteps = 42\nlr = 0.005\n[eval]\ndownstream = true\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.steps, 42);
        assert!((c.lr - 5e-3).abs() < 1e-12);
        assert!(c.downstream);
        assert_eq!(c.prefetch, 4); // default survives
    }

    #[test]
    fn validation_rejects_nonsense() {
        let c = ExperimentConfig {
            steps: 0,
            ..ExperimentConfig::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            lr: -1.0,
            ..ExperimentConfig::default()
        };
        assert!(c.validate().is_err());
    }
}
