//! The training framework (L3): experiment config, LR schedule, the
//! training orchestrator (prefetching data loader thread + train loop +
//! checkpointing + logging), and the downstream evaluation harness.

pub mod config;
pub mod evalharness;
pub mod runlog;
pub mod runstore;
pub mod schedule;
pub mod trainer;

pub use config::ExperimentConfig;
pub use evalharness::{eval_downstream, DownstreamResult};
pub use runstore::{bench_config, RunRecord, RunStore};
pub use schedule::Schedule;
pub use trainer::{RunResult, Trainer};
