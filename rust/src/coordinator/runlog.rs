//! Structured run logging: JSONL event stream + stdout progress lines.

use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

pub struct RunLog {
    file: Option<File>,
    pub quiet: bool,
}

impl RunLog {
    pub fn create(dir: &Path, quiet: bool) -> Result<RunLog> {
        fs::create_dir_all(dir)?;
        let file = File::create(dir.join("log.jsonl"))?;
        Ok(RunLog {
            file: Some(file),
            quiet,
        })
    }

    /// Log sink that discards (for benches that keep their own tables).
    pub fn null() -> RunLog {
        RunLog {
            file: None,
            quiet: true,
        }
    }

    pub fn event(&mut self, kind: &str, fields: Vec<(&str, Json)>) {
        let mut kvs = vec![("event", Json::str(kind))];
        kvs.extend(fields);
        let line = Json::obj(kvs).to_string();
        if let Some(f) = self.file.as_mut() {
            let _ = writeln!(f, "{line}");
        }
    }

    pub fn step(&mut self, step: usize, loss: f32, gnorm: f32, lr: f64, ms: f64) {
        self.event(
            "step",
            vec![
                ("step", Json::num(step as f64)),
                ("loss", Json::num(loss as f64)),
                ("gnorm", Json::num(gnorm as f64)),
                ("lr", Json::num(lr)),
                ("ms", Json::num(ms)),
            ],
        );
        if !self.quiet && (step % 25 == 0) {
            println!("  step {step:>5}  loss {loss:.4}  gnorm {gnorm:.3}  lr {lr:.2e}  {ms:.0} ms");
        }
    }
}
