//! # Metis — FP4/FP8 LLM training via spectral decomposition
//!
//! Rust + JAX + Pallas reproduction of *"Metis: Training LLMs with FP4
//! Quantization"* (Chen et al., 2025).  See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for measured-vs-paper results.
//!
//! Layering (Python never on the request path):
//! * **L1** Pallas kernels + **L2** JAX model live in `python/compile/`,
//!   AOT-lowered once to HLO text artifacts by `make artifacts`.
//! * **L3** (this crate) is the coordinator: it loads artifacts through
//!   the PJRT CPU client ([`runtime`]), drives training ([`coordinator`]),
//!   generates data ([`data`]), evaluates downstream probes ([`probe`]),
//!   and reproduces every figure/table with the analysis substrates
//!   ([`linalg`], [`formats`], [`spectral`]).
//! * The [`metis`] subsystem composes those substrates into the paper's
//!   full algorithm natively — spectral splits (Eqs. 3/6), §3.1
//!   decomposition strategies, sub-distribution quantization
//!   (Eqs. 5/8–11), the §3.2 adaptive spectral LR, and the
//!   layer-sharded `quantize-model` pipeline.

pub mod artifact;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod formats;
pub mod linalg;
pub mod metis;
pub mod obs;
pub mod probe;
pub mod runtime;
pub mod spectral;
pub mod tensor;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
