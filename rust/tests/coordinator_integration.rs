//! End-to-end coordinator integration against the nano artifacts:
//! trainer loop (loader thread → train_step → state feedback), schedule,
//! checkpointing, eval, and the downstream probe harness.
//! Skipped with a notice when `make artifacts` hasn't run.

use metis::coordinator::{eval_downstream, ExperimentConfig, Trainer};
use metis::data::tasks::TaskKind;
use metis::runtime::Engine;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn cfg(mode: &str, steps: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.model = "nano".into();
    c.mode = mode.into();
    c.steps = steps;
    c.lr = 1e-2;
    c.warmup = 5;
    c.out_dir = std::env::temp_dir()
        .join("metis_coord_test")
        .to_string_lossy()
        .into_owned();
    c.name = format!("it_{mode}");
    c
}

#[test]
fn trainer_runs_and_learns_fp32() {
    let Some(eng) = engine() else { return };
    let mut t = Trainer::new(&eng, cfg("fp32", 60)).expect("trainer");
    let res = t.train().expect("train");
    assert_eq!(res.losses.len(), 60);
    assert!(!res.diverged);
    assert!(
        res.final_train_loss() < res.losses[0] * 0.75,
        "loss {} -> {}",
        res.losses[0],
        res.final_train_loss()
    );
    assert!(res.test_loss.is_finite());
    // log written
    let log = std::path::Path::new(&t.cfg.out_dir)
        .join(format!("{}__nano__fp32", t.cfg.name))
        .join("log.jsonl");
    let text = std::fs::read_to_string(log).expect("log.jsonl");
    assert!(text.lines().count() >= 60);
    assert!(text.contains("\"event\":\"done\""));
}

#[test]
fn deterministic_across_trainers() {
    let Some(eng) = engine() else { return };
    let run = || {
        let mut t = Trainer::new(&eng, cfg("fp32", 10)).unwrap();
        let mut log = metis::coordinator::runlog::RunLog::null();
        t.train_with_log(&mut log).unwrap().losses
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same config+seed must give identical loss curves");
}

#[test]
fn checkpoint_roundtrip() {
    let Some(eng) = engine() else { return };
    let mut t = Trainer::new(&eng, cfg("fp32", 8)).unwrap();
    let mut log = metis::coordinator::runlog::RunLog::null();
    let _ = t.train_with_log(&mut log).unwrap();
    let dir = t.checkpoint(8).unwrap();
    // every param present and loadable with matching shape
    for (name, hv) in t.param_names.iter().zip(t.params()) {
        let arr = metis::util::npy::read_npy(dir.join(format!("{name}.npy")))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(arr.shape, hv.shape(), "{name}");
    }
    // loss of reloaded params equals trainer's eval loss
    let before = t.eval_loss(2).unwrap();
    let reloaded: Vec<_> = t
        .param_names
        .iter()
        .map(|n| {
            metis::runtime::HostValue::from_npy(
                &metis::util::npy::read_npy(dir.join(format!("{n}.npy"))).unwrap(),
            )
        })
        .collect();
    t.state[..reloaded.len()].clone_from_slice(&reloaded);
    let after = t.eval_loss(2).unwrap();
    assert_eq!(before, after);
}

#[test]
fn metis_mode_trains_and_probes() {
    let Some(eng) = engine() else { return };
    let mut t = Trainer::new(&eng, cfg("nvfp4_metis", 40)).expect("trainer");
    let mut log = metis::coordinator::runlog::RunLog::null();
    let res = t.train_with_log(&mut log).expect("train");
    assert!(!res.diverged);
    assert!(res.final_train_loss() < res.losses[0]);

    // downstream probes on two representative tasks (full sweep is the
    // table benches' job; this guards the harness plumbing).
    let results = eval_downstream(
        &eng,
        "nano",
        "nvfp4_metis",
        t.params(),
        7,
        &[TaskKind::Sst2Like, TaskKind::MnliLike],
    )
    .expect("downstream");
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!((0.2..=1.0).contains(&r.accuracy), "{:?}: {}", r.task, r.accuracy);
    }
}

#[test]
fn schedule_reaches_peak_and_decays() {
    use metis::coordinator::Schedule;
    let s = Schedule::new(2e-3, 50, 400);
    assert_eq!(s.lr_at(0), 0.0);
    assert!((s.lr_at(50) - 2e-3).abs() < 1e-12);
    assert!(s.lr_at(399) < 2e-5);
}
