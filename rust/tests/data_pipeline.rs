//! Integration over the data substrate without the engine: corpus →
//! batcher → tasks → probe.  Verifies the synthetic pipeline carries
//! enough signal for the downstream harness to be meaningful.

use metis::data::corpus::{Corpus, CorpusConfig};
use metis::data::tasks::{Task, TaskKind, ALL_TASKS};
use metis::data::BatchIterator;
use metis::probe::{Probe, ProbeConfig};

/// Bag-of-words featurizer — a model-free stand-in for the features
/// artifact, used to check each task is decodable at all.
fn bow_features(examples: &[Vec<i32>], vocab: usize, dim: usize) -> Vec<f32> {
    // Random-but-fixed projection of token counts to `dim`.
    let proj: Vec<f32> = (0..vocab * dim)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
            ((h >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        })
        .collect();
    let mut out = Vec::with_capacity(examples.len() * dim);
    for ex in examples {
        let mut counts = vec![0f32; vocab];
        for &t in ex {
            counts[t as usize] += 1.0;
        }
        for j in 0..dim {
            let mut acc = 0.0;
            for (t, &c) in counts.iter().enumerate() {
                if c != 0.0 {
                    acc += c * proj[t * dim + j];
                }
            }
            out.push(acc);
        }
    }
    out
}

#[test]
fn loader_batches_match_direct_generation() {
    // The coordinator's loader thread must produce exactly the batches
    // the deterministic iterator describes.
    let c = Corpus::new(CorpusConfig::new(256, 7));
    let direct: Vec<Vec<i32>> = {
        let mut it = BatchIterator::new(&c, 8, 32, 0);
        (0..5).map(|_| it.next_batch()).collect()
    };
    // Same thing through a thread + channel (mimicking spawn_loader).
    let (tx, rx) = std::sync::mpsc::sync_channel(2);
    let cfg = CorpusConfig::new(256, 7);
    std::thread::spawn(move || {
        let c = Corpus::new(cfg);
        let mut it = BatchIterator::new(&c, 8, 32, 0);
        for _ in 0..5 {
            tx.send(it.next_batch()).unwrap();
        }
    });
    for want in direct {
        assert_eq!(rx.recv().unwrap(), want);
    }
}

#[test]
fn every_task_linearly_decodable_from_bow() {
    // If even a bag-of-words probe can beat chance, the task carries
    // signal; the model-feature probes then measure representation
    // quality rather than task impossibility.
    let c = Corpus::new(CorpusConfig::new(256, 7));
    for kind in ALL_TASKS {
        let task = Task::generate(&c, kind, 48, 0);
        let dim = 32;
        let ftr = bow_features(
            &task.train.iter().map(|e| e.tokens.clone()).collect::<Vec<_>>(),
            256,
            dim,
        );
        let fev = bow_features(
            &task.eval.iter().map(|e| e.tokens.clone()).collect::<Vec<_>>(),
            256,
            dim,
        );
        let ytr: Vec<usize> = task.train.iter().map(|e| e.label).collect();
        let yev: Vec<usize> = task.eval.iter().map(|e| e.label).collect();
        let (p, norm) = Probe::train(&ftr, &ytr, dim, kind.n_classes(), &ProbeConfig::default());
        let acc = p.accuracy(&norm, &fev, &yev);
        let chance = 1.0 / kind.n_classes() as f64;
        // Only *lexical* tasks are decodable from bag-of-words: CoLA* is
        // word-order, MRPC*/QNLI*/RTE* are relational (require comparing
        // pair halves — that is what the transformer features are for).
        if matches!(kind, TaskKind::Sst2Like | TaskKind::MnliLike) {
            assert!(
                acc > chance + 0.08,
                "{kind:?}: BoW probe acc {acc:.3} ~ chance {chance:.3}"
            );
        } else {
            assert!(acc > chance - 0.08, "{kind:?}: acc {acc:.3} below chance");
        }
    }
}

#[test]
fn corpus_vocab_scales() {
    for vocab in [128usize, 256, 512, 2048] {
        let c = Corpus::new(CorpusConfig::new(vocab, 1));
        let s = c.gen_stream(&mut c.doc_rng(0, 0), 512);
        assert!(s.iter().all(|&t| (t as usize) < vocab));
        // all open-class pools non-trivial
        assert!(c.noun.len > 8);
        assert!(c.verb.len > 4);
    }
}

#[test]
fn batches_have_no_padding_in_train_stream() {
    let c = Corpus::new(CorpusConfig::new(512, 9));
    let mut it = BatchIterator::new(&c, 4, 128, 0);
    let b = it.next_batch();
    // train streams are packed sentences — PAD never appears
    assert!(b.iter().all(|&t| t != metis::data::corpus::PAD));
}
