//! End-to-end tests of the pure-Rust Metis engine: checkpoint-dir →
//! sharded pipeline → JSONL reports, thread-count invariance and
//! speedup sanity, and cross-validation of the split+quantize numerics
//! against the semantics documented in python/compile/metis.py.

use metis::formats::{self, Format};
use metis::linalg::jacobi_svd;
use metis::metis::{
    gradient_split, pipeline, quantizer, weight_split, DecompStrategy, MetisQuantConfig,
    PipelineConfig,
};
use metis::tensor::Matrix;
use metis::util::json::Json;
use metis::util::prng::Rng;

fn cfg(threads: usize) -> PipelineConfig {
    PipelineConfig {
        quant: MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.12,
            max_rank: 24,
        },
        threads,
        measure_sigma: true,
        sigma_dim_cap: 128,
        seed: 11,
    }
}

#[test]
fn pipeline_end_to_end_on_checkpoint_dir() {
    // Write a small "checkpoint" of npy weight blobs, sweep it through
    // the pipeline, and validate the JSONL report.
    let dir = std::env::temp_dir().join("metis_e2e_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(0);
    for (name, m, n) in [("wq", 48usize, 48usize), ("wfc", 48, 96), ("wproj", 96, 48)] {
        pipeline::planted_powerlaw(&mut rng, m, n, 1.5)
            .save_npy(dir.join(format!("{name}.npy")))
            .unwrap();
    }
    // A bias vector must be ignored by the loader.
    Matrix::gaussian(&mut rng, 1, 48, 1.0)
        .save_npy(dir.join("b.npy"))
        .unwrap();

    let layers = pipeline::load_checkpoint_dir(&dir).unwrap();
    assert_eq!(layers.len(), 3);
    let res = pipeline::run(layers, &cfg(2)).unwrap();
    assert_eq!(res.reports.len(), 3);

    let out = dir.join("report.jsonl");
    res.write_jsonl(&out).unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 3);
    for (line, rep) in text.lines().zip(&res.reports) {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.req("name").unwrap().as_str().unwrap(), rep.name);
        assert_eq!(j.req("k").unwrap().as_usize().unwrap(), rep.k);
        // σ measured (dims under the cap): finite numbers in the JSON.
        assert!(j.req("metis_sigma_err").unwrap().as_f64().unwrap().is_finite());
    }

    // The headline claim end-to-end: Metis σ-distortion beats direct on
    // every anisotropic layer.
    for r in &res.reports {
        assert!(
            r.metis_sigma_err < r.direct_sigma_err,
            "{}: σ-err metis {} !< direct {}",
            r.name,
            r.metis_sigma_err,
            r.direct_sigma_err
        );
        assert!(r.metis_underflow <= r.direct_underflow, "{}", r.name);
    }
}

#[test]
fn pipeline_reports_are_thread_count_invariant() {
    // Per-layer RNG streams are fold_in(index)-derived, so any worker
    // count produces bit-identical reports in the same order.
    let res1 = pipeline::run(pipeline::synthetic_model(2, 32, 5), &cfg(1)).unwrap();
    let res3 = pipeline::run(pipeline::synthetic_model(2, 32, 5), &cfg(3)).unwrap();
    assert_eq!(res1.reports.len(), 8);
    assert_eq!(res3.reports.len(), 8);
    for (a, b) in res1.reports.iter().zip(&res3.reports) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.k, b.k);
        assert_eq!(a.metis_rel_err, b.metis_rel_err);
        assert_eq!(a.direct_rel_err, b.direct_rel_err);
        assert_eq!(a.metis_sigma_err, b.metis_sigma_err);
        assert_eq!(a.direct_sigma_err, b.direct_sigma_err);
    }
}

#[test]
fn split_quantize_numerics_match_python_semantics() {
    // python/compile/metis.py (make_decomp_linear): the effective Eq. 5
    // weight is Q(U)·S·Q(Vᵀ) + Q(W_R), with every Q blocked along the
    // GEMM contraction axis (U: axis 0 = m; Vᵀ: axis 0 = k; W_R:
    // axis 0 = m) and S exempt from quantization.  Recompose the same
    // thing by hand from the public formats API and require bit
    // equality with the engine's quantize_split.
    let mut rng = Rng::new(3);
    let w = pipeline::planted_powerlaw(&mut rng, 96, 64, 1.5);
    let split = weight_split(&w, 9, DecompStrategy::Rsvd, &mut rng);
    for fmt in Format::ALL {
        let engine = quantizer::quantize_split(&split, fmt);
        let by_hand = formats::quantize_matrix_along(fmt, &split.svd.u, 0)
            .scale_cols(&split.svd.s)
            .matmul(&formats::quantize_matrix_along(
                fmt,
                &split.svd.v.transpose(),
                0,
            ))
            .add(&formats::quantize_matrix_along(fmt, &split.residual, 0));
        assert_eq!(engine, by_hand, "{}", fmt.name());
    }

    // Gradient side (Eq. 6 semantics from python/compile/spectral.py):
    // P diag(t) Qᵀ + D_R reconstructs D exactly, t̃ fixes σ₁ and only
    // amplifies the tail (≤ 2×), factors are orthonormal/unit.
    let d = pipeline::planted_powerlaw(&mut rng, 48, 40, 1.5).scale(1e-5);
    let dec = gradient_split(&d, 6, 1, true, &mut rng);
    let rec_err = dec.reconstruct(false).sub(&d).frob_norm() / d.frob_norm();
    assert!(rec_err < 1e-9, "Eq. 6 reconstruction: {rec_err:.2e}");
    let t1 = dec.t.iter().cloned().fold(0.0f64, f64::max);
    let a1 = dec.t_adapt.iter().cloned().fold(0.0f64, f64::max);
    assert!((t1 - a1).abs() / t1 < 1e-9);
    for (t, a) in dec.t.iter().zip(&dec.t_adapt) {
        assert!(*a >= *t - 1e-12 && *a <= 2.0 * t + 1e-12);
    }
    // Unit rows of Qᵀ.
    for i in 0..dec.qt.rows {
        let norm: f64 = (0..dec.qt.cols).map(|j| dec.qt.at(i, j).powi(2)).sum();
        assert!((norm.sqrt() - 1.0).abs() < 1e-8, "row {i}: {norm}");
    }
}

#[test]
fn sparse_sample_matches_full_svd_through_the_whole_path() {
    // Strategy choice must not change the *measured* quality class:
    // sparse-sampled splits land within 20% of the full-SVD splits' σ
    // distortion on every format.
    let mut rng = Rng::new(4);
    let w = pipeline::planted_powerlaw(&mut rng, 96, 96, 1.5);
    let reference = jacobi_svd(&w).s;
    for fmt in [Format::Mxfp4, Format::Fp8] {
        let full = weight_split(&w, 12, DecompStrategy::Full, &mut rng);
        let samp = weight_split(&w, 12, DecompStrategy::SparseSample, &mut rng);
        let (sig_full, _) =
            quantizer::sigma_distortion(&reference, &quantizer::quantize_split(&full, fmt));
        let (sig_samp, _) =
            quantizer::sigma_distortion(&reference, &quantizer::quantize_split(&samp, fmt));
        assert!(
            sig_samp < sig_full * 1.5 + 1e-3,
            "{}: sampled {sig_samp:.4} vs full {sig_full:.4}",
            fmt.name()
        );
    }
}
