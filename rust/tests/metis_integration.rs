//! End-to-end tests of the pure-Rust Metis engine: checkpoint-dir →
//! sharded pipeline → JSONL reports, thread-count invariance and
//! speedup sanity, and cross-validation of the split+quantize numerics
//! against the semantics documented in python/compile/metis.py.

use metis::data::evalsplit::scan_eval_split;
use metis::formats::{self, Format};
use metis::linalg::jacobi_svd;
use metis::metis::{
    gradient_split, pipeline, quantizer, train_native, train_native_evented, train_native_with,
    weight_split, DecompStrategy, EvalConfig, EvalState, GradStepConfig, LayerSpec,
    MetisQuantConfig, NativeEvent, NativeTrainConfig, Optim, PipelineConfig, SigmaRef, StepReport,
    TrainState,
};
use metis::tensor::Matrix;
use metis::util::json::Json;
use metis::util::npy::NpyWriter;
use metis::util::prng::Rng;

fn cfg(threads: usize) -> PipelineConfig {
    PipelineConfig {
        quant: MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.12,
            max_rank: 24,
        },
        threads,
        measure_sigma: true,
        sigma_dim_cap: 128,
        seed: 11,
        block_cols: 0,
        sigma_ref: SigmaRef::Sampled,
    }
}

#[test]
fn pipeline_end_to_end_on_checkpoint_dir() {
    // Write a small "checkpoint" of npy weight blobs, sweep it through
    // the pipeline, and validate the JSONL report.
    let dir = std::env::temp_dir().join("metis_e2e_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(0);
    for (name, m, n) in [("wq", 48usize, 48usize), ("wfc", 48, 96), ("wproj", 96, 48)] {
        pipeline::planted_powerlaw(&mut rng, m, n, 1.5)
            .save_npy(dir.join(format!("{name}.npy")))
            .unwrap();
    }
    // A bias vector must be ignored by the loader.
    Matrix::gaussian(&mut rng, 1, 48, 1.0)
        .save_npy(dir.join("b.npy"))
        .unwrap();

    let layers = pipeline::load_checkpoint_dir(&dir).unwrap();
    assert_eq!(layers.len(), 3);
    let res = pipeline::run(layers, &cfg(2)).unwrap();
    assert_eq!(res.reports.len(), 3);

    let out = dir.join("report.jsonl");
    res.write_jsonl(&out).unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 3);
    for (line, rep) in text.lines().zip(&res.reports) {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.req("name").unwrap().as_str().unwrap(), rep.name);
        assert_eq!(j.req("k").unwrap().as_usize().unwrap(), rep.k);
        // σ measured (dims under the cap): finite numbers in the JSON.
        assert!(j.req("metis_sigma_err").unwrap().as_f64().unwrap().is_finite());
    }

    // The headline claim end-to-end: Metis σ-distortion beats direct on
    // every anisotropic layer.
    for r in &res.reports {
        assert!(
            r.metis_sigma_err < r.direct_sigma_err,
            "{}: σ-err metis {} !< direct {}",
            r.name,
            r.metis_sigma_err,
            r.direct_sigma_err
        );
        assert!(r.metis_underflow <= r.direct_underflow, "{}", r.name);
    }
}

#[test]
fn pipeline_reports_are_thread_count_invariant() {
    // Per-layer RNG streams are fold_in(index)-derived, so any worker
    // count produces bit-identical reports in the same order.
    let res1 = pipeline::run(pipeline::synthetic_model(2, 32, 5), &cfg(1)).unwrap();
    let res3 = pipeline::run(pipeline::synthetic_model(2, 32, 5), &cfg(3)).unwrap();
    assert_eq!(res1.reports.len(), 8);
    assert_eq!(res3.reports.len(), 8);
    for (a, b) in res1.reports.iter().zip(&res3.reports) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.k, b.k);
        assert_eq!(a.metis_rel_err, b.metis_rel_err);
        assert_eq!(a.direct_rel_err, b.direct_rel_err);
        assert_eq!(a.metis_sigma_err, b.metis_sigma_err);
        assert_eq!(a.direct_sigma_err, b.direct_sigma_err);
    }
}

#[test]
fn blocked_pipeline_disk_and_mem_paths_agree() {
    // The same checkpoint swept (a) resident, via load_checkpoint_dir,
    // and (b) streaming, via scan_checkpoint_dir — with column blocking
    // on, every (layer, block) unit must see the same bytes and the
    // reports must match bit-for-bit, on any thread count.
    let dir = std::env::temp_dir().join("metis_blocked_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(8);
    for (name, m, n) in [("wide", 24usize, 96usize), ("square", 32, 32)] {
        pipeline::planted_powerlaw(&mut rng, m, n, 1.5)
            .save_npy(dir.join(format!("{name}.npy")))
            .unwrap();
    }
    let mut c = cfg(3);
    c.block_cols = 32; // "wide" fans out into 3 column blocks

    let mem = pipeline::run(pipeline::load_checkpoint_dir(&dir).unwrap(), &c).unwrap();
    let disk = pipeline::run_specs(pipeline::scan_checkpoint_dir(&dir).unwrap(), &c).unwrap();
    let mut c1 = c;
    c1.threads = 1;
    let disk1 = pipeline::run_specs(pipeline::scan_checkpoint_dir(&dir).unwrap(), &c1).unwrap();
    assert_eq!(mem.reports.len(), 2);
    for ((a, b), d1) in mem.reports.iter().zip(&disk.reports).zip(&disk1.reports) {
        for r in [b, d1] {
            assert_eq!(a.name, r.name);
            assert_eq!((a.rows, a.cols), (r.rows, r.cols));
            assert_eq!(a.k, r.k);
            assert_eq!(a.metis_rel_err, r.metis_rel_err);
            assert_eq!(a.direct_rel_err, r.direct_rel_err);
            assert_eq!(a.metis_underflow, r.metis_underflow);
            assert_eq!(a.metis_sigma_err, r.metis_sigma_err);
            assert_eq!(a.direct_sigma_err, r.direct_sigma_err);
        }
    }
}

#[test]
fn checkpoint_scan_is_directory_order_independent() {
    // scan_checkpoint_dir sorts the readdir stream (metis-lint rule
    // read-dir-unsorted, DESIGN.md §12): the spec list must depend only
    // on the file names, never on creation order or the filesystem's
    // directory enumeration.  Same checkpoint written in opposite
    // creation orders must scan to identical spec lists.
    let names = ["alpha", "mid", "zeta"];
    let mut rng = Rng::new(21);
    let mats: Vec<Matrix> = names
        .iter()
        .map(|_| pipeline::planted_powerlaw(&mut rng, 24, 24, 1.5))
        .collect();
    let mk = |tag: &str, order: &[usize]| {
        let dir = std::env::temp_dir().join(format!("metis_scan_order_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for &i in order {
            mats[i]
                .save_npy(dir.join(format!("{}.npy", names[i])))
                .unwrap();
        }
        dir
    };
    let fwd = pipeline::scan_checkpoint_dir(mk("fwd", &[0, 1, 2])).unwrap();
    let rev = pipeline::scan_checkpoint_dir(mk("rev", &[2, 1, 0])).unwrap();
    let sig = |specs: &[LayerSpec]| {
        specs
            .iter()
            .map(|s| (s.name.clone(), s.rows, s.cols))
            .collect::<Vec<_>>()
    };
    assert_eq!(sig(&fwd), sig(&rev), "spec list depends on creation order");
    let got: Vec<&str> = fwd.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(got, names, "specs must come back sorted by file name");
}

#[test]
fn streamed_blocked_sweep_reports_finite_sampled_sigma_above_cap() {
    // A streamed layer above --sigma-cap, sharded into column blocks:
    // σ columns come back finite through the sampled reference (they
    // were silently NaN before), the Metis path still wins them on an
    // anisotropic layer, and the blocked+sampled pipeline stays
    // thread-count invariant end-to-end.
    let dir = std::env::temp_dir().join("metis_sampled_sigma_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(17);
    pipeline::planted_powerlaw(&mut rng, 40, 120, 1.5)
        .save_npy(dir.join("w.npy"))
        .unwrap();
    let mut c = cfg(4);
    c.sigma_dim_cap = 16; // every 40×40 block is "large"
    c.block_cols = 40;
    c.sigma_ref = SigmaRef::Sampled;
    let res = pipeline::run_specs(pipeline::scan_checkpoint_dir(&dir).unwrap(), &c).unwrap();
    assert_eq!(res.reports.len(), 1);
    let r = &res.reports[0];
    assert!(r.metis_sigma_err.is_finite() && r.metis_sigma_err > 0.0, "NaN σ: {r:?}");
    assert!(r.direct_sigma_err.is_finite() && r.direct_sigma_err > 0.0);
    assert!(r.metis_sigma_tail.is_finite() && r.direct_sigma_tail.is_finite());
    assert!(
        r.metis_sigma_err < r.direct_sigma_err,
        "sampled σ-err metis {} !< direct {}",
        r.metis_sigma_err,
        r.direct_sigma_err
    );
    let mut c1 = c;
    c1.threads = 1;
    let r1 = pipeline::run_specs(pipeline::scan_checkpoint_dir(&dir).unwrap(), &c1).unwrap();
    assert_eq!(r.metis_sigma_err, r1.reports[0].metis_sigma_err);
    assert_eq!(r.metis_rel_err, r1.reports[0].metis_rel_err);
    // --sigma-ref full keeps the historical NaN above the cap.
    let mut cf = c;
    cf.sigma_ref = SigmaRef::Full;
    let rf = pipeline::run_specs(pipeline::scan_checkpoint_dir(&dir).unwrap(), &cf).unwrap();
    assert!(rf.reports[0].metis_sigma_err.is_nan());
    assert_eq!(rf.reports[0].metis_rel_err, r.metis_rel_err);
}

fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

#[test]
#[ignore = "4096x4096 streaming sweep — run in the release CI job"]
fn blocked_4k_layer_streams_with_bounded_memory() {
    // The acceptance scenario: a paper-scale 4096² layer, generated
    // row-by-row through the streaming writer (never resident), (a)
    // packed through the streamed init-time Eq. 3 path as 4096×512
    // column blocks, then (b) swept through quantize→measure→report as
    // 8 streamed column blocks with the sampled σ reference.  The job
    // log gets a VmHWM note after each phase so memory regressions on
    // either streaming path are visible in CI.
    let dir = std::env::temp_dir().join("metis_4k_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w4096.npy");
    let n = 4096usize;
    {
        let mut w = NpyWriter::create_f32(&path, &[n, n]).unwrap();
        let mut rng = Rng::new(42);
        let mut row = vec![0f32; n];
        for _ in 0..n {
            for x in row.iter_mut() {
                *x = rng.gauss_f32(0.0, 1.0);
            }
            w.write_f32(&row).unwrap();
        }
        w.finish().unwrap();
    }

    // --- phase (a): streamed init-time packing --------------------------
    // Runs first so its VmHWM reading is not masked by the sweep's.
    // Resident by design: the f64 master + cached effective weight
    // (2 × 128 MB); transient: one 4096×512 split workspace per worker.
    // The pre-streaming path materialized whole-matrix split workspaces
    // (residual + low-rank + effective + factor copies ≈ 5 × 128 MB on
    // top), so the envelope below fails if init regresses to it.
    {
        let specs = pipeline::scan_checkpoint_dir(&dir).unwrap();
        let quant = MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.05,
            max_rank: 32,
        };
        let watch = std::time::Instant::now();
        // 2 packing workers: enough to prove sharding, small enough
        // that the per-worker block workspaces keep the envelope well
        // under the ≈ 770 MB the whole-matrix packing path peaks at.
        let state = TrainState::init_specs(
            specs,
            quant,
            GradStepConfig::default(),
            Optim::Sgd,
            1,
            512,
            2,
        )
        .unwrap();
        assert_eq!(state.layers.len(), 1);
        let pw = &state.layers[0];
        assert_eq!(pw.blocks.len(), n / 512);
        assert_eq!((pw.master.rows, pw.master.cols), (n, n));
        // Accuracy probe on one column block (a whole-matrix sub would
        // add a 128 MB transient right before the RSS reading).
        let rel = pw.effective().col_block(0, 512).sub(&pw.master.col_block(0, 512)).frob_norm()
            / pw.master.col_block(0, 512).frob_norm();
        assert!(rel.is_finite() && rel > 0.0 && rel < 0.5, "packing error: {rel:.3}");
        match peak_rss_kb() {
            Some(kb) => {
                let mb = kb as f64 / 1024.0;
                println!(
                    "RSS note: VmHWM {mb:.0} MB after streamed 4096x4096 packed init \
                     ({} blocks of 4096x512, {:.0} ms; master+effective resident = 256 MB)",
                    n / 512,
                    watch.elapsed().as_secs_f64() * 1e3,
                );
                // PR 3 streaming envelope: master + effective (256 MB)
                // plus per-worker block workspaces — a regression to
                // whole-matrix split workspaces (≥ 5 extra 128 MB
                // buffers, ≈ 770 MB+) trips this.
                assert!(
                    mb < 640.0,
                    "packed init VmHWM {mb:.0} MB exceeds the streaming envelope"
                );
            }
            None => println!("RSS note: /proc/self/status unavailable on this platform"),
        }
    }

    // --- phase (b): streamed quantize→measure→report sweep --------------
    let specs = pipeline::scan_checkpoint_dir(&dir).unwrap();
    assert_eq!(specs.len(), 1);
    assert_eq!((specs[0].rows, specs[0].cols), (n, n));
    let c = PipelineConfig {
        quant: MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.05,
            max_rank: 32,
        },
        threads: 4,
        measure_sigma: true,
        sigma_dim_cap: 256,
        seed: 1,
        block_cols: 512,
        sigma_ref: SigmaRef::Sampled,
    };
    let res = pipeline::run_specs(specs, &c).unwrap();
    assert_eq!(res.reports.len(), 1);
    let r = &res.reports[0];
    assert_eq!((r.rows, r.cols), (n, n));
    assert!(r.k >= 1);
    assert!(r.metis_rel_err.is_finite() && r.metis_rel_err > 0.0);
    assert!(r.direct_rel_err.is_finite() && r.direct_rel_err > 0.0);
    // The headline fix: σ columns are finite via the sampled reference
    // where the full-Jacobi path had to skip (NaN).
    assert!(r.metis_sigma_err.is_finite(), "σ went NaN on the 4k layer");
    assert!(r.direct_sigma_err.is_finite());
    match peak_rss_kb() {
        Some(kb) => println!(
            "RSS note: VmHWM {:.0} MB after streaming the 4096x4096 sweep \
             ({} blocks of 4096x512, {:.0} ms; f32 blob itself is 64 MB)",
            kb as f64 / 1024.0,
            n / 512,
            res.wall_ms
        ),
        None => println!("RSS note: /proc/self/status unavailable on this platform"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn split_quantize_numerics_match_python_semantics() {
    // python/compile/metis.py (make_decomp_linear): the effective Eq. 5
    // weight is Q(U)·S·Q(Vᵀ) + Q(W_R), with every Q blocked along the
    // GEMM contraction axis (U: axis 0 = m; Vᵀ: axis 0 = k; W_R:
    // axis 0 = m) and S exempt from quantization.  Recompose the same
    // thing by hand from the public formats API and require bit
    // equality with the engine's quantize_split.
    let mut rng = Rng::new(3);
    let w = pipeline::planted_powerlaw(&mut rng, 96, 64, 1.5);
    let split = weight_split(&w, 9, DecompStrategy::Rsvd, &mut rng);
    for fmt in Format::ALL {
        let engine = quantizer::quantize_split(&split, fmt);
        let by_hand = formats::quantize_matrix_along(fmt, &split.svd.u, 0)
            .scale_cols(&split.svd.s)
            .matmul(&formats::quantize_matrix_along(
                fmt,
                &split.svd.v.transpose(),
                0,
            ))
            .add(&formats::quantize_matrix_along(fmt, &split.residual, 0));
        assert_eq!(engine, by_hand, "{}", fmt.name());
    }

    // Gradient side (Eq. 6 semantics from python/compile/spectral.py):
    // P diag(t) Qᵀ + D_R reconstructs D exactly, t̃ fixes σ₁ and only
    // amplifies the tail (≤ 2×), factors are orthonormal/unit.
    let d = pipeline::planted_powerlaw(&mut rng, 48, 40, 1.5).scale(1e-5);
    let dec = gradient_split(&d, 6, 1, true, &mut rng);
    let rec_err = dec.reconstruct(false).sub(&d).frob_norm() / d.frob_norm();
    assert!(rec_err < 1e-9, "Eq. 6 reconstruction: {rec_err:.2e}");
    let t1 = dec.t.iter().cloned().fold(0.0f64, f64::max);
    let a1 = dec.t_adapt.iter().cloned().fold(0.0f64, f64::max);
    assert!((t1 - a1).abs() / t1 < 1e-9);
    for (t, a) in dec.t.iter().zip(&dec.t_adapt) {
        assert!((*t - 1e-12..=2.0 * t + 1e-12).contains(a));
    }
    // Unit rows of Qᵀ.
    for i in 0..dec.qt.rows {
        let norm: f64 = (0..dec.qt.cols).map(|j| dec.qt.at(i, j).powi(2)).sum();
        assert!((norm.sqrt() - 1.0).abs() < 1e-8, "row {i}: {norm}");
    }
}

fn native_cfg(threads: usize) -> NativeTrainConfig {
    // The acceptance configuration of the native W4A4G4 loop, scaled
    // down one notch (d_model 48, 30 steps) to keep the test quick.
    NativeTrainConfig {
        n_layers: 2,
        d_model: 48,
        steps: 30,
        batch: 32,
        lr: 0.02,
        warmup: 5,
        seed: 0,
        threads,
        quant: MetisQuantConfig {
            fmt: Format::PaperFp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.1,
            max_rank: 64,
        },
        grad: GradStepConfig {
            rank: 8,
            power_iters: 1,
            adaptive: true,
            fmt: Format::PaperFp4,
        },
        optim: Optim::Sgd,
        repack_every: 0,
        pack_block_cols: 1024,
    }
}

#[test]
fn native_loop_loss_curve_is_bit_identical_across_thread_counts() {
    // The tentpole determinism contract: per-(layer, step) fold_in
    // streams + layer-ordered aggregation make the loss curve — and
    // every per-layer σ̃/split statistic — independent of sharding.
    let r1 = train_native(&native_cfg(1)).unwrap();
    let r4 = train_native(&native_cfg(4)).unwrap();
    assert_eq!(r1.reports.len(), 30);
    assert_eq!(r1.losses(), r4.losses(), "loss curves diverged across thread counts");
    for (a, b) in r1.reports.iter().zip(&r4.reports) {
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name);
            assert_eq!(la.loss, lb.loss);
            assert_eq!(la.t1, lb.t1);
            assert_eq!(la.amp_mean, lb.amp_mean);
            assert_eq!(la.captured, lb.captured);
        }
    }
    // And the loop actually trains under full W4A4G4.
    assert!(!r1.diverged);
    assert!(r1.losses().iter().all(|x| x.is_finite()));
    assert!(
        r1.final_loss() < 0.8 * r1.first_loss(),
        "loss not decreasing: {} -> {}",
        r1.first_loss(),
        r1.final_loss()
    );
}

#[test]
fn shared_pool_interleaves_pipeline_and_training_without_crosstalk() {
    // The pipeline and the native loop now share one persistent
    // process-wide WorkPool.  Interleaving sweeps and training runs —
    // and running them concurrently from two OS threads — must leave
    // every report bit-identical to the isolated runs: the pool carries
    // no per-caller state, and all RNG streams derive per work unit.
    let sweep = || pipeline::run(pipeline::synthetic_model(1, 16, 5), &cfg(3)).unwrap();
    let train = || {
        let mut c = native_cfg(2);
        c.steps = 4;
        c.d_model = 16;
        train_native(&c).unwrap()
    };
    let (base_sweep, base_train) = (sweep(), train());
    let (again_train, again_sweep) = std::thread::scope(|s| {
        let t = s.spawn(train);
        let p = s.spawn(sweep);
        (t.join().unwrap(), p.join().unwrap())
    });
    assert_eq!(base_train.losses(), again_train.losses());
    for (a, b) in base_sweep.reports.iter().zip(&again_sweep.reports) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.metis_rel_err, b.metis_rel_err);
        assert_eq!(a.metis_sigma_err, b.metis_sigma_err);
    }
}

#[test]
fn native_loop_with_periodic_repack_stays_deterministic() {
    // The full Eq. 3 re-pack draws from the same per-(layer, step)
    // stream inside the workers — sharding must not reorder it.
    let mut c1 = native_cfg(1);
    c1.steps = 12;
    c1.repack_every = 4;
    let mut c2 = c1;
    c2.threads = 3;
    let r1 = train_native(&c1).unwrap();
    let r2 = train_native(&c2).unwrap();
    assert_eq!(r1.losses(), r2.losses());
    assert!(!r1.diverged);
}

#[test]
fn native_loop_streams_valid_jsonl_reports() {
    let mut cfg = native_cfg(2);
    cfg.steps = 6;
    cfg.d_model = 24;
    let mut lines: Vec<String> = Vec::new();
    let mut on_step = |rep: &StepReport| lines.push(rep.to_json().to_string());
    let res = train_native_with(&cfg, &mut on_step).unwrap();
    assert_eq!(lines.len(), 6);
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.req("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(j.req("step").unwrap().as_usize().unwrap(), i);
        assert!(j.req("loss").unwrap().as_f64().unwrap().is_finite());
        let layers = j.req("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 8); // 2 blocks × 4 matrices
        for l in layers {
            // The per-layer σ̃ rescale stats + split timing contract.
            let amp = l.req("amp_mean").unwrap().as_f64().unwrap();
            assert!((1.0..=2.0).contains(&amp));
            assert!(l.req("t1").unwrap().as_f64().unwrap() >= 0.0);
            assert!(l.req("split_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(l.req("captured").unwrap().as_f64().unwrap() > 0.0);
        }
    }
    // write_jsonl mirrors the stream.
    let dir = std::env::temp_dir().join("metis_native_train");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("steps.jsonl");
    res.write_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 6);
    assert_eq!(text.lines().next().unwrap(), lines[0]);
}

#[test]
fn train_native_eval_every_streams_heldout_rows() {
    // The tentpole wiring: --eval-every N interleaves held-out eval
    // rows with the step rows.  The fidelity curve must be valid JSONL,
    // decrease as the masters converge on the planted targets, and be
    // bit-identical across thread counts (every field except the wall
    // time).
    let cfg = |threads| NativeTrainConfig {
        n_layers: 1,
        d_model: 24,
        steps: 12,
        batch: 16,
        lr: 0.03,
        warmup: 2,
        seed: 9,
        threads,
        quant: MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.15,
            max_rank: 16,
        },
        grad: GradStepConfig::default(),
        optim: Optim::Sgd,
        repack_every: 0,
        pack_block_cols: 1024,
    };
    let ecfg = |threads| EvalConfig {
        threads,
        batch: 16,
        batches: 3,
        seed: 9,
        sigma_dim_cap: 256,
        block_cols: 1024,
        fmt: Format::Nvfp4,
    };
    let run = |threads| {
        let harness = EvalState::synthetic(ecfg(threads)).unwrap();
        let mut lines: Vec<String> = Vec::new();
        let res = train_native_evented(&cfg(threads), Some((4, &harness)), &mut |ev| {
            if let NativeEvent::Eval(er) = ev {
                lines.push(er.to_json().to_string());
            }
        })
        .unwrap();
        (res, lines)
    };
    let (r1, lines1) = run(1);
    let (r3, _) = run(3);

    assert_eq!(r1.evals.len(), 3); // steps 3, 7, 11
    assert_eq!(lines1.len(), 3);
    for (i, line) in lines1.iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.req("event").unwrap().as_str().unwrap(), "eval");
        assert_eq!(j.req("step").unwrap().as_usize().unwrap(), 4 * i + 3);
        assert!(j.req("heldout_loss").unwrap().as_f64().unwrap().is_finite());
        let layers = j.req("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 4);
        for l in layers {
            assert!(l.req("sigma_err").unwrap().as_f64().unwrap() > 0.0);
            assert!(l.req("logit_div").unwrap().as_f64().unwrap() > 0.0);
        }
    }
    // Fidelity curve: held-out loss falls as the masters converge.
    assert!(
        r1.evals.last().unwrap().heldout_loss < r1.evals[0].heldout_loss,
        "held-out loss did not decrease: {} -> {}",
        r1.evals[0].heldout_loss,
        r1.evals.last().unwrap().heldout_loss
    );
    // Thread-count bit-identity of every value (eval_ms excepted).
    assert_eq!(r1.losses(), r3.losses());
    for (a, b) in r1.evals.iter().zip(&r3.evals) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.heldout_loss, b.heldout_loss);
        assert_eq!(a.perplexity, b.perplexity);
        assert_eq!(a.logit_div, b.logit_div);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name);
            assert_eq!(la.loss, lb.loss);
            assert_eq!(la.logit_div, lb.logit_div);
            assert_eq!(la.sigma_err, lb.sigma_err);
            assert_eq!(la.sigma_tail, lb.sigma_tail);
        }
    }
    // write_eval_jsonl mirrors the streamed rows.
    let dir = std::env::temp_dir().join("metis_native_eval");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("evals.jsonl");
    r1.write_eval_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 3);
}

#[test]
fn eval_loss_is_bit_identical_for_1_vs_4_workers_on_the_same_split() {
    // The satellite contract: the same on-disk validation split, 1 vs 4
    // eval workers → bit-identical eval loss (and every other value).
    let dir = std::env::temp_dir().join("metis_eval_split_threads");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(31);
    // Widths matching the d16 synthetic model: rows 16 (qkv/attn/ffn_in)
    // and 64 (ffn_out).
    for (name, b, d) in [("x16", 8usize, 16usize), ("x64", 6, 64)] {
        Matrix::gaussian(&mut rng, b, d, 1.0)
            .save_npy(dir.join(format!("{name}.npy")))
            .unwrap();
    }
    let specs = || -> Vec<LayerSpec> {
        pipeline::synthetic_model(1, 16, 7)
            .into_iter()
            .map(|l| LayerSpec::mem(l.name, l.w))
            .collect()
    };
    let quant = MetisQuantConfig {
        fmt: Format::PaperFp4,
        strategy: DecompStrategy::SparseSample,
        rho: 0.15,
        max_rank: 16,
    };
    let run = |threads| {
        let cfg = EvalConfig {
            threads,
            block_cols: 24, // wide layers fan out into several units
            sigma_dim_cap: 8, // exercises the sampled σ reference too
            ..EvalConfig::default()
        };
        EvalState::with_split(cfg, scan_eval_split(&dir).unwrap())
            .unwrap()
            .eval_specs(&specs(), &quant, 7, None)
            .unwrap()
    };
    let (r1, r4) = (run(1), run(4));
    assert_eq!(r1.heldout_loss, r4.heldout_loss, "eval loss diverged across workers");
    assert_eq!(r1.perplexity, r4.perplexity);
    assert_eq!(r1.logit_div, r4.logit_div);
    for (a, b) in r1.layers.iter().zip(&r4.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.sigma_err, b.sigma_err);
    }
    // And the rows are meaningful: finite, positive fidelity columns.
    assert!(r1.heldout_loss.is_finite() && r1.heldout_loss > 0.0);
    assert!(r1.logit_div > 0.0 && r1.logit_div < 1.0);

    // A mismatched split must fail train-native at startup — before a
    // single step runs — not at the first scheduled eval.
    let bad_cfg = NativeTrainConfig {
        n_layers: 1,
        d_model: 24, // no 24- or 96-wide batches in this split
        steps: 8,
        seed: 1,
        ..NativeTrainConfig::default()
    };
    let harness = EvalState::with_split(EvalConfig::default(), scan_eval_split(&dir).unwrap())
        .unwrap();
    let mut steps_seen = 0usize;
    let err = train_native_evented(&bad_cfg, Some((4, &harness)), &mut |ev| {
        if matches!(ev, NativeEvent::Step(_)) {
            steps_seen += 1;
        }
    })
    .unwrap_err();
    assert_eq!(steps_seen, 0, "mismatched split must fail before step 0");
    let msg = format!("{err:#}");
    assert!(msg.contains("width 24"), "{msg}");
}

#[test]
fn streamed_packed_init_from_disk_matches_resident_packing() {
    // init_specs over a scanned checkpoint dir (streamed column blocks,
    // 3 threads) must produce the same packed state as the same
    // matrices packed resident — and training from it must behave
    // identically.
    let dir = std::env::temp_dir().join("metis_packed_init_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(23);
    let mats: Vec<(String, Matrix)> = [("wide", 24usize, 72usize), ("square", 32, 32)]
        .into_iter()
        .map(|(name, m, n)| {
            let w = pipeline::planted_powerlaw(&mut rng, m, n, 1.5);
            w.save_npy(dir.join(format!("{name}.npy"))).unwrap();
            (name.to_string(), w)
        })
        .collect();
    let quant = MetisQuantConfig {
        fmt: Format::Nvfp4,
        strategy: DecompStrategy::SparseSample,
        rho: 0.12,
        max_rank: 24,
    };
    let g = GradStepConfig::default();
    let disk = TrainState::init_specs(
        pipeline::scan_checkpoint_dir(&dir).unwrap(),
        quant,
        g,
        Optim::Sgd,
        5,
        32,
        3,
    )
    .unwrap();
    // Resident copy: identical f32-roundtripped payloads via mem specs.
    let mem_specs: Vec<LayerSpec> = pipeline::scan_checkpoint_dir(&dir)
        .unwrap()
        .iter()
        .map(|s| LayerSpec::mem(s.name.clone(), s.read_all().unwrap()))
        .collect();
    let mem = TrainState::init_specs(mem_specs, quant, g, Optim::Sgd, 5, 32, 1).unwrap();
    assert_eq!(disk.layers.len(), 2);
    // Name-sorted scan: "square" first, then "wide" (3 blocks).
    assert_eq!(disk.layers[0].name, "square");
    assert_eq!(disk.layers[0].blocks.len(), 1);
    assert_eq!(disk.layers[1].blocks.len(), 3);
    for ((d, m), (_, want)) in disk.layers.iter().zip(&mem.layers).zip(
        mats.iter()
            .filter(|(n, _)| n.as_str() == "square")
            .chain(mats.iter().filter(|(n, _)| n.as_str() == "wide")),
    ) {
        assert_eq!(d.name, m.name);
        assert_eq!(d.master, m.master);
        assert_eq!(d.effective(), m.effective());
        // The master is the f32 roundtrip of what was written.
        let err = d
            .master
            .sub(&Matrix::from_f32(
                want.rows,
                want.cols,
                &want.data.iter().map(|&x| x as f32).collect::<Vec<_>>(),
            ))
            .frob_norm();
        assert!(err < 1e-12, "{}: master diverges from blob: {err:.2e}", d.name);
        for (bd, bm) in d.blocks.iter().zip(&m.blocks) {
            assert_eq!(bd.s, bm.s);
            assert_eq!(bd.uq, bm.uq);
            assert_eq!(bd.vtq, bm.vtq);
        }
    }
}

#[test]
fn sparse_sample_matches_full_svd_through_the_whole_path() {
    // Strategy choice must not change the *measured* quality class:
    // sparse-sampled splits land within 20% of the full-SVD splits' σ
    // distortion on every format.
    let mut rng = Rng::new(4);
    let w = pipeline::planted_powerlaw(&mut rng, 96, 96, 1.5);
    let reference = jacobi_svd(&w).s;
    for fmt in [Format::Mxfp4, Format::Fp8] {
        let full = weight_split(&w, 12, DecompStrategy::Full, &mut rng);
        let samp = weight_split(&w, 12, DecompStrategy::SparseSample, &mut rng);
        let (sig_full, _) =
            quantizer::sigma_distortion(&reference, &quantizer::quantize_split(&full, fmt));
        let (sig_samp, _) =
            quantizer::sigma_distortion(&reference, &quantizer::quantize_split(&samp, fmt));
        assert!(
            sig_samp < sig_full * 1.5 + 1e-3,
            "{}: sampled {sig_samp:.4} vs full {sig_full:.4}",
            fmt.name()
        );
    }
}
