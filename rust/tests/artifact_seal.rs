//! End-to-end contract of the sealed-artifact subsystem (ISSUE 10):
//! `metis pack` → `metis eval --artifact` must be **bit-identical** to
//! pack-on-the-fly eval of the same checkpoint, and every tamper path
//! (truncation, flipped bytes, length drift, unknown versions, stale
//! manifests) must be rejected with a named error — never a panic,
//! never a silent load.  Exercised through the public library API the
//! CLI subcommands call.

use std::fs;
use std::path::PathBuf;

use metis::artifact::{
    blob_name, write_artifact, ArtifactReader, PackOptions, MANIFEST_FILE,
};
use metis::formats::Format;
use metis::metis::{
    pipeline, DecompStrategy, EvalConfig, EvalState, MetisQuantConfig,
};
use metis::tensor::Matrix;
use metis::util::json::Json;
use metis::util::prng::Rng;

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("metis-it-artifact-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a small on-disk .npy checkpoint (two layers, one wide enough
/// to partition into multiple column blocks at block_cols 24).
fn write_ckpt(dir: &PathBuf) {
    let mut rng = Rng::new(1234);
    Matrix::gaussian(&mut rng.fold_in(0), 32, 56, 1.0)
        .save_npy(dir.join("layer_a.npy"))
        .unwrap();
    Matrix::gaussian(&mut rng.fold_in(1), 24, 24, 0.7)
        .save_npy(dir.join("layer_b.npy"))
        .unwrap();
}

fn pack_opts() -> PackOptions {
    PackOptions {
        quant: MetisQuantConfig {
            fmt: Format::Nvfp4,
            strategy: DecompStrategy::SparseSample,
            rho: 0.25,
            max_rank: 16,
        },
        seed: 77,
        block_cols: 24,
        threads: 2,
    }
}

fn eval_cfg(threads: usize) -> EvalConfig {
    EvalConfig {
        threads,
        batch: 8,
        batches: 2,
        seed: 77,
        sigma_dim_cap: 256,
        block_cols: 24,
        fmt: Format::Nvfp4,
    }
}

/// Strip the per-process / per-wall-clock fields (`run_id`, `seq`,
/// `ms`) from a stamped eval row, leaving exactly the deterministic
/// payload two runs must agree on byte for byte.
fn normalized_row(j: &Json) -> Json {
    match j {
        Json::Obj(kvs) => Json::Obj(
            kvs.iter()
                .filter(|(k, _)| k != "run_id" && k != "seq" && k != "ms")
                .map(|(k, v)| (k.clone(), normalized_row(v)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(normalized_row).collect()),
        other => other.clone(),
    }
}

#[test]
fn artifact_eval_row_is_bit_identical_to_pack_on_the_fly() {
    let ckpt = fresh_dir("ckpt");
    let art = fresh_dir("sealed");
    write_ckpt(&ckpt);
    let specs = pipeline::scan_checkpoint_dir(ckpt.to_str().unwrap()).unwrap();
    let opts = pack_opts();
    let summary = write_artifact(&specs, &opts, &art).unwrap();
    assert_eq!(summary.manifest.layers.len(), 2);
    // 56 cols at block_cols 24 → 3 blocks for layer_a.
    assert_eq!(summary.manifest.layers[0].blocks.len(), 3);

    // Pack-on-the-fly row at the pack seed/config...
    let fly = EvalState::synthetic(eval_cfg(2))
        .unwrap()
        .eval_specs(&specs, &opts.quant, opts.seed, None)
        .unwrap();
    // ...vs the sealed-artifact row.
    let reader = ArtifactReader::open(&art).unwrap();
    let sealed = EvalState::synthetic(eval_cfg(2))
        .unwrap()
        .eval_artifact(&reader, None)
        .unwrap();

    // Exact f64 equality on every deterministic report field: the
    // artifact path recomposes the identical effective weights, so no
    // tolerance is needed or allowed.
    assert_eq!(fly.heldout_loss.to_bits(), sealed.heldout_loss.to_bits());
    assert_eq!(fly.perplexity.to_bits(), sealed.perplexity.to_bits());
    assert_eq!(fly.logit_div.to_bits(), sealed.logit_div.to_bits());
    assert_eq!(fly.batches, sealed.batches);
    assert_eq!(fly.layers.len(), sealed.layers.len());
    for (a, b) in fly.layers.iter().zip(&sealed.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{}", a.name);
        assert_eq!(a.logit_div.to_bits(), b.logit_div.to_bits(), "{}", a.name);
        assert_eq!(a.sigma_err.to_bits(), b.sigma_err.to_bits(), "{}", a.name);
        assert_eq!(a.sigma_tail.to_bits(), b.sigma_tail.to_bits(), "{}", a.name);
    }
    // And the JSONL rows themselves agree once the per-process
    // identity fields (run_id / seq) and wall-clock ms are stripped.
    assert_eq!(
        normalized_row(&fly.to_json()).to_string(),
        normalized_row(&sealed.to_json()).to_string()
    );

    // The sealed row is also thread-count invariant, like every other
    // eval path.
    let sealed_1t = EvalState::synthetic(eval_cfg(1))
        .unwrap()
        .eval_artifact(&reader, None)
        .unwrap();
    assert_eq!(
        normalized_row(&sealed.to_json()).to_string(),
        normalized_row(&sealed_1t.to_json()).to_string()
    );

    let _ = fs::remove_dir_all(&ckpt);
    let _ = fs::remove_dir_all(&art);
}

/// Pack once into a temp dir and hand back (artifact dir, ckpt dir).
fn sealed_fixture(tag: &str) -> (PathBuf, PathBuf) {
    let ckpt = fresh_dir(&format!("{tag}-ckpt"));
    let art = fresh_dir(&format!("{tag}-art"));
    write_ckpt(&ckpt);
    let specs = pipeline::scan_checkpoint_dir(ckpt.to_str().unwrap()).unwrap();
    write_artifact(&specs, &pack_opts(), &art).unwrap();
    (art, ckpt)
}

fn cleanup(dirs: &[&PathBuf]) {
    for d in dirs {
        let _ = fs::remove_dir_all(d);
    }
}

#[test]
fn truncated_blob_is_a_named_error() {
    let (art, ckpt) = sealed_fixture("trunc");
    let path = art.join(blob_name(0, 1));
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let err = format!("{:#}", ArtifactReader::open(&art).unwrap_err());
    assert!(err.contains("truncated or stale"), "{err}");
    cleanup(&[&art, &ckpt]);
}

#[test]
fn flipped_payload_byte_is_a_named_error() {
    let (art, ckpt) = sealed_fixture("flip");
    let path = art.join(blob_name(1, 0));
    let mut bytes = fs::read(&path).unwrap();
    let at = bytes.len() - 9;
    bytes[at] ^= 0x01;
    fs::write(&path, &bytes).unwrap();
    // Same length ⇒ the open-time stat passes; the verified load must
    // catch the flip.
    let reader = ArtifactReader::open(&art).unwrap();
    let err = format!("{:#}", reader.load_block(1, 0).unwrap_err());
    assert!(err.contains("checksum mismatch"), "{err}");
    cleanup(&[&art, &ckpt]);
}

#[test]
fn manifest_blob_length_mismatch_is_a_named_error() {
    let (art, ckpt) = sealed_fixture("len");
    // Appending bytes keeps the prefix parseable — only the manifest
    // length / checksum contract can reject it.
    let path = art.join(blob_name(0, 0));
    let mut bytes = fs::read(&path).unwrap();
    bytes.push(0);
    fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", ArtifactReader::open(&art).unwrap_err());
    assert!(err.contains("truncated or stale"), "{err}");
    cleanup(&[&art, &ckpt]);
}

#[test]
fn edited_manifest_and_unknown_schema_version_are_named_errors() {
    let (art, ckpt) = sealed_fixture("manifest");
    let mpath = art.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath).unwrap();

    // Any hand edit breaks the canonical-JSON self-checksum.
    fs::write(&mpath, text.replace("\"seed\":77", "\"seed\":78")).unwrap();
    let err = format!("{:#}", ArtifactReader::open(&art).unwrap_err());
    assert!(err.contains("manifest checksum mismatch"), "{err}");

    // A future schema_version is refused by name before anything else
    // is trusted.
    fs::write(
        &mpath,
        text.replace("\"schema_version\":1", "\"schema_version\":99"),
    )
    .unwrap();
    let err = format!("{:#}", ArtifactReader::open(&art).unwrap_err());
    assert!(err.contains("unsupported artifact schema_version 99"), "{err}");
    cleanup(&[&art, &ckpt]);
}

#[test]
fn stale_manifest_vs_blob_drift_is_a_named_error() {
    let (art, ckpt) = sealed_fixture("drift");
    // Re-seal the manifest with a lied-about rank for one block: the
    // self-checksum is then valid again (to_json recomputes it), the
    // blob still hashes correctly — only the blob-header-vs-manifest
    // drift check can catch that the manifest no longer describes the
    // sealed payload.
    let reader = ArtifactReader::open(&art).unwrap();
    let mut manifest = reader.manifest().clone();
    let k = manifest.layers[1].blocks[0].k;
    assert!(k > 1, "fixture rank too small to perturb");
    manifest.layers[1].blocks[0].k = k - 1;
    fs::write(
        art.join(MANIFEST_FILE),
        manifest.to_json().to_string().as_bytes(),
    )
    .unwrap();
    let reopened = ArtifactReader::open(&art).unwrap();
    let err = format!("{:#}", reopened.load_block(1, 0).unwrap_err());
    assert!(err.contains("does not match its manifest slot"), "{err}");
    cleanup(&[&art, &ckpt]);
}

#[test]
fn missing_blob_is_a_named_error() {
    let (art, ckpt) = sealed_fixture("gone");
    fs::remove_file(art.join(blob_name(0, 2))).unwrap();
    let err = format!("{:#}", ArtifactReader::open(&art).unwrap_err());
    assert!(err.contains("missing"), "{err}");
    cleanup(&[&art, &ckpt]);
}
