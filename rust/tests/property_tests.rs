//! Property-based tests over the Rust substrates (hand-rolled harness —
//! proptest is not vendorable offline).  Each property runs many random
//! cases from a deterministic PRNG; failure messages carry the seed.

use metis::formats::{self, codecs, Format};
use metis::linalg::{householder_qr, jacobi_svd, kernels, randomized_svd};
use metis::metis::{pipeline::planted_powerlaw, quantizer, weight_split, DecompStrategy};
use metis::spectral;
use metis::tensor::Matrix;
use metis::util::json::Json;
use metis::util::npy::{read_npy, write_npy, NpyArray};
use metis::util::prng::Rng;

const P_SEED: u64 = 0x9E3779B97F4A7C15;

fn seed(s: u64) -> Rng {
    Rng::new(P_SEED ^ s)
}

// -- formats ------------------------------------------------------------------

#[test]
fn prop_fp4_always_on_grid_and_nearest() {
    let grid = codecs::fp4_grid();
    for s in 0..2000u64 {
        let mut rng = seed(s);
        let x = (rng.f32() - 0.5) * 16.0;
        let q = codecs::fp4_e2m1(x);
        assert!(grid.contains(&q.abs()), "fp4({x}) = {q}");
        let xc = x.clamp(-6.0, 6.0);
        let best = grid
            .iter()
            .flat_map(|&g| [g, -g])
            .map(|g| (g - xc).abs())
            .fold(f32::INFINITY, f32::min);
        assert!((q - xc).abs() <= best + 1e-6, "fp4({x}) = {q} not nearest");
    }
}

#[test]
fn prop_fp8_monotone() {
    // Quantization must preserve ordering (monotone non-decreasing).
    for s in 0..500u64 {
        let mut rng = seed(s);
        let a = (rng.f32() - 0.5) * 1000.0;
        let b = (rng.f32() - 0.5) * 1000.0;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(codecs::fp8_e4m3(lo) <= codecs::fp8_e4m3(hi));
    }
}

#[test]
fn prop_block_quant_scale_invariance_mx() {
    // MXFP4 uses power-of-two scales: quantizing 2^k·x == 2^k·quantize(x).
    for s in 0..200u64 {
        let mut rng = seed(s);
        let xs: Vec<f32> = (0..32).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let k = i32::try_from(rng.below(9)).unwrap() - 4;
        let factor = (k as f32).exp2();
        let scaled: Vec<f32> = xs.iter().map(|x| x * factor).collect();
        let q1 = formats::quantize_block(Format::Mxfp4, &xs);
        let q2 = formats::quantize_block(Format::Mxfp4, &scaled);
        for (a, b) in q1.iter().zip(&q2) {
            let expect = a * factor;
            assert!(
                (b - expect).abs() <= 1e-6 * expect.abs().max(1e-3),
                "scale invariance broke: {a} {b} k={k} seed {s}"
            );
        }
    }
}

#[test]
fn prop_quant_never_increases_amax_much() {
    for s in 0..200u64 {
        let mut rng = seed(s);
        let n = 16 + rng.usize(200);
        let xs: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 3.0)).collect();
        for fmt in [Format::Mxfp4, Format::Nvfp4, Format::Fp8] {
            let q = formats::quantize_block(fmt, &xs);
            let amax_x = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let amax_q = q.iter().fold(0f32, |a, &x| a.max(x.abs()));
            // Worst overshoot: value just above a grid midpoint rounds up
            // (e.g. amax/s = 5.01 → 6, ratio 1.198) — bound is 6/5.
            assert!(
                amax_q <= amax_x * 1.2 + 1e-6,
                "{}: amax grew {amax_x} -> {amax_q} (seed {s})",
                fmt.name()
            );
        }
    }
}

// -- kernels --------------------------------------------------------------------

#[test]
fn prop_tiled_gemm_matches_naive_reference() {
    // The tiled/pool kernel family pinned to the preserved scalar
    // reference across random shapes, including the degenerate ones the
    // register tiling must pad around: 1×n, m×1, k=0, and every
    // non-multiple-of-tile edge the random draw lands on.
    for s in 0..40u64 {
        let mut rng = seed(s);
        let (m, k, n) = match s % 5 {
            0 => (1, 1 + rng.usize(40), 1 + rng.usize(40)), // 1×n row
            1 => (1 + rng.usize(40), 1 + rng.usize(40), 1), // m×1 col
            2 => (1 + rng.usize(20), 0, 1 + rng.usize(20)), // k = 0
            _ => (1 + rng.usize(70), 1 + rng.usize(70), 1 + rng.usize(70)),
        };
        let a = Matrix::gaussian(&mut rng, m, k, 1.0);
        let b = Matrix::gaussian(&mut rng, k, n, 1.0);
        let want = kernels::matmul_ref(&a, &b);
        for (name, got) in [
            ("matmul", a.matmul(&b)),
            ("serial", kernels::matmul_serial(&a, &b)),
            ("at_b", a.transpose().matmul_at_b(&b)),
            ("a_bt", a.matmul_a_bt(&b.transpose())),
        ] {
            assert_eq!((got.rows, got.cols), (m, n), "seed {s} {name}");
            let err = got.sub(&want).frob_norm() / want.frob_norm().max(1e-300);
            assert!(err < 1e-12, "seed {s} {name} {m}x{k}x{n}: {err:.2e}");
        }
    }
}

#[test]
fn prop_fused_quantizer_bit_identical_to_naive() {
    // Exact equality (not tolerance): the fused single-walk quantizer
    // performs the same f32 ops in the same order as the per-block-Vec
    // reference, for random lengths and both matrix axes.
    for s in 0..60u64 {
        let mut rng = seed(s);
        let fmt = Format::ALL[rng.usize(Format::ALL.len())];
        let len = rng.usize(400);
        let xs: Vec<f32> = (0..len).map(|_| rng.gauss_f32(0.0, 2.0)).collect();
        assert_eq!(
            formats::quantize_block(fmt, &xs),
            formats::quantize_block_ref(fmt, &xs),
            "seed {s} {} len {len}",
            fmt.name()
        );
        let (m, n) = (1 + rng.usize(50), 1 + rng.usize(50));
        let a = Matrix::gaussian(&mut rng, m, n, 1.5);
        let axis = rng.usize(2);
        assert_eq!(
            formats::quantize_matrix_along(fmt, &a, axis),
            formats::quantize_matrix_along_ref(fmt, &a, axis),
            "seed {s} {} {m}x{n} axis {axis}",
            fmt.name()
        );
    }
}

#[test]
fn prop_blocked_transpose_is_exact() {
    for s in 0..40u64 {
        let mut rng = seed(s);
        let (m, n) = (1 + rng.usize(90), 1 + rng.usize(90));
        let a = Matrix::gaussian(&mut rng, m, n, 1.0);
        let t = a.transpose();
        for r in 0..m {
            for c in 0..n {
                assert_eq!(t.at(c, r), a.at(r, c), "seed {s} {m}x{n}");
            }
        }
    }
}

#[test]
fn prop_incremental_jacobi_matches_reference_spectrum() {
    // The incremental-norm sweep pinned against the preserved 3-dot
    // reference across random shapes (both orientations).
    for s in 0..12u64 {
        let mut rng = seed(s);
        let (m, n) = (2 + rng.usize(28), 2 + rng.usize(28));
        let a = Matrix::gaussian(&mut rng, m, n, 1.0);
        let fast = jacobi_svd(&a);
        let oracle = metis::linalg::svd::jacobi_svd_ref(&a);
        for (i, (x, y)) in fast.s.iter().zip(&oracle.s).enumerate() {
            assert!(
                (x - y).abs() < 1e-9 * y.max(1.0),
                "seed {s} {m}x{n} σ{i}: {x} vs {y}"
            );
        }
    }
}

// -- linalg ---------------------------------------------------------------------

#[test]
fn prop_svd_reconstructs_random_shapes() {
    for s in 0..30u64 {
        let mut rng = seed(s);
        let m = 3 + rng.usize(30);
        let n = 3 + rng.usize(30);
        let a = Matrix::gaussian(&mut rng, m, n, 1.0);
        let svd = jacobi_svd(&a);
        let err = svd.reconstruct(m.min(n)).sub(&a).frob_norm() / a.frob_norm();
        assert!(err < 1e-9, "{m}x{n}: {err}");
    }
}

#[test]
fn prop_svd_frobenius_identity() {
    // ‖A‖_F² == Σσᵢ² (rotation invariance).
    for s in 0..30u64 {
        let mut rng = seed(s);
        let (m, n) = (5 + rng.usize(20), 5 + rng.usize(20));
        let a = Matrix::gaussian(&mut rng, m, n, 2.0);
        let svd = jacobi_svd(&a);
        let sum: f64 = svd.s.iter().map(|x| x * x).sum();
        let f2 = a.frob_norm().powi(2);
        assert!((sum - f2).abs() / f2 < 1e-10);
    }
}

#[test]
fn prop_rsvd_captures_planted_energy() {
    for s in 0..15u64 {
        let mut rng = seed(s);
        let (m, n, k) = (30 + rng.usize(40), 20 + rng.usize(30), 4);
        let r = m.min(n);
        let spectrum: Vec<f64> = (1..=r).map(|i| 20.0 * (i as f64).powf(-2.0)).collect();
        let q1 = householder_qr(&Matrix::gaussian(&mut rng, m, r, 1.0)).q;
        let q2 = householder_qr(&Matrix::gaussian(&mut rng, n, r, 1.0)).q;
        let a = q1.scale_cols(&spectrum).matmul(&q2.transpose());
        let approx = randomized_svd(&a, k, 8, 2, &mut rng);
        for i in 0..k {
            let rel = (approx.s[i] - spectrum[i]).abs() / spectrum[i];
            assert!(
                rel < 1e-4,
                "seed {s} σ{i}: {} vs {}",
                approx.s[i],
                spectrum[i]
            );
        }
    }
}

#[test]
fn prop_quantization_bias_hits_small_singulars_harder() {
    // The Fig. 4B property as a statistical invariant over random
    // anisotropic matrices: mean relative σ error of the bottom half of
    // the spectrum exceeds the top-3 mean in almost all draws.
    let mut worse = 0;
    let total = 10u64;
    for s in 0..total {
        let mut rng = seed(s);
        let (m, n) = (48, 48);
        let spectrum: Vec<f64> = (1..=n).map(|i| 30.0 * (i as f64).powf(-1.5)).collect();
        let q1 = householder_qr(&Matrix::gaussian(&mut rng, m, n, 1.0)).q;
        let q2 = householder_qr(&Matrix::gaussian(&mut rng, n, n, 1.0)).q;
        let a = q1.scale_cols(&spectrum).matmul(&q2.transpose());
        let q = formats::quantize_matrix_along(Format::Mxfp4, &a, 0);
        let s1 = jacobi_svd(&a).s;
        let s2 = jacobi_svd(&q).s;
        let errs = spectral::sigma_rel_errors(&s1, &s2);
        let top: f64 = errs[..3].iter().sum::<f64>() / 3.0;
        let tail: f64 = errs[n / 2..].iter().sum::<f64>() / (n - n / 2) as f64;
        if tail > top {
            worse += 1;
        }
    }
    assert!(worse >= 8, "tail errors larger in only {worse}/{total} cases");
}

#[test]
fn prop_metis_split_beats_direct_quant_all_formats() {
    // The Fig. 5 claim as a property over planted power-law
    // (anisotropic) matrices, for all four block formats: the Metis
    // split-then-quantize path yields strictly lower σ-spectrum
    // reconstruction error and σ-distortion than direct block
    // quantization — mean relative σ error over the whole spectrum and
    // over its tail half, each by at least 2× — plus no worse
    // small-value clipping (§2.3's underflow bias).
    //
    // Deliberately *not* asserted: element-space Frobenius error, which
    // direct quantization wins by construction (quantizing two factors
    // costs ≈ √2 of quantizing the product once).  The paper's point is
    // that direct quantization's lower elementwise error hides a
    // catastrophic spectral bias — its white error floor swamps every
    // tail σ — while the split keeps the noise structured.  See
    // DESIGN.md §8.
    for s in 0..3u64 {
        let mut rng = seed(s);
        let w = planted_powerlaw(&mut rng, 64, 64, 1.5);
        let reference = jacobi_svd(&w).s;
        let split = weight_split(&w, 10, DecompStrategy::Full, &mut rng);
        for fmt in Format::ALL {
            let metis_q = quantizer::quantize_split(&split, fmt);
            let direct_q = quantizer::quantize_direct(&w, fmt);
            let (mean_m, tail_m) = quantizer::sigma_distortion(&reference, &metis_q);
            let (mean_d, tail_d) = quantizer::sigma_distortion(&reference, &direct_q);
            assert!(
                mean_m < 0.5 * mean_d,
                "seed {s} {}: mean σ err {mean_m:.4} !< ½·{mean_d:.4}",
                fmt.name()
            );
            assert!(
                tail_m < 0.5 * tail_d,
                "seed {s} {}: tail σ err {tail_m:.4} !< ½·{tail_d:.4}",
                fmt.name()
            );
            let st_m = formats::blockq::quant_stats(&w, &metis_q);
            let st_d = formats::blockq::quant_stats(&w, &direct_q);
            assert!(
                st_m.underflow_frac <= st_d.underflow_frac,
                "seed {s} {}: underflow {} > {}",
                fmt.name(),
                st_m.underflow_frac,
                st_d.underflow_frac
            );
        }
    }
}

// -- util ------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.gauss() * 100.0 * 64.0).round() / 64.0),
            3 => Json::Str(format!("s{}-\"x\"\n", rng.below(1000))),
            4 => Json::Arr(
                (0..rng.usize(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.usize(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for s in 0..200u64 {
        let mut rng = seed(s);
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {s}: {e}\n{text}"));
        assert_eq!(v, back, "seed {s}");
    }
}

#[test]
fn prop_npy_roundtrip_random_shapes() {
    let dir = std::env::temp_dir().join("metis_prop_npy");
    std::fs::create_dir_all(&dir).unwrap();
    for s in 0..40u64 {
        let mut rng = seed(s);
        let ndim = 1 + rng.usize(3);
        let shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.usize(8)).collect();
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.gauss_f32(0.0, 10.0)).collect();
        let arr = NpyArray::f32(shape.clone(), data.clone());
        let p = dir.join(format!("p{s}.npy"));
        write_npy(&p, &arr).unwrap();
        let back = read_npy(&p).unwrap();
        assert_eq!(back.shape, shape);
        assert_eq!(back.to_f32(), data);
    }
}

#[test]
fn prop_elbow_fraction_bounded() {
    for s in 0..50u64 {
        let mut rng = seed(s);
        let r = 10 + rng.usize(200);
        let mut spec: Vec<f64> = (0..r).map(|_| rng.f64() * 10.0 + 1e-6).collect();
        spec.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let (k, f) = spectral::elbow_fraction(&spec);
        assert!(k < r);
        assert!((0.0..1.0).contains(&f));
    }
}

#[test]
fn prop_popoviciu_holds_for_random_matrices() {
    for s in 0..30u64 {
        let mut rng = seed(s);
        let (m, n) = (10 + rng.usize(30), 10 + rng.usize(30));
        let a = Matrix::gaussian(&mut rng, m, n, 1.5);
        let svd = jacobi_svd(&a);
        let (_, bound, actual) = spectral::popoviciu_check(&a, &svd.s);
        assert!(actual >= bound - 1e-9, "seed {s}: {actual} < {bound}");
    }
}
