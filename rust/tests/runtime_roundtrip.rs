//! Integration: the AOT bridge end-to-end — manifest → HLO text →
//! PJRT compile → execute — against the nano artifacts built by
//! `make artifacts` (skipped with a notice if artifacts are missing).
//!
//! Also the cross-language bit-exactness check: the Pallas quantizer
//! artifact vs the Rust `formats` implementation on the same inputs.

use metis::formats::{self, Format};
use metis::runtime::{Engine, HostValue};
use metis::util::prng::Rng;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

#[test]
fn quantizer_artifact_matches_rust_codecs() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(7);
    let data: Vec<f32> = (0..256 * 256).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let input = HostValue::F32 {
        shape: vec![256, 256],
        data: data.clone(),
    };
    for (name, fmt) in [
        ("quantize__mxfp4__256x256", Format::Mxfp4),
        ("quantize__nvfp4__256x256", Format::Nvfp4),
        ("quantize__fp8__256x256", Format::Fp8),
    ] {
        let out = eng.run(name, &[input.clone()]).expect(name);
        let got = out[0].f32s().unwrap();
        // Rust mirror: blocks along rows (the kernel's lane axis).
        let mut want = Vec::with_capacity(data.len());
        for row in data.chunks(256) {
            want.extend(formats::quantize_block(fmt, row));
        }
        // Near-bit-exact: XLA may rewrite x/s into x·rcp(s) (1-ulp scale
        // roundoff) and libm log2 can differ at razor-edge binade
        // boundaries — tolerate 1-ulp-scale deviations, forbid real ones.
        let mut mismatches = 0usize;
        let mut max_err = 0f32;
        for (&a, &b) in got.iter().zip(&want) {
            let tol = 1e-5 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                mismatches += 1;
                max_err = max_err.max((a - b).abs());
            }
        }
        let frac = mismatches as f64 / data.len() as f64;
        assert!(
            frac < 1e-4,
            "{name}: {mismatches} mismatches ({frac:.2e}), max {max_err}"
        );
    }
}

#[test]
fn qgemm_artifact_matches_quantized_matmul() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..256 * 256).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let w: Vec<f32> = (0..256 * 256).map(|_| rng.gauss_f32(0.0, 0.1)).collect();
    let hx = HostValue::F32 {
        shape: vec![256, 256],
        data: x.clone(),
    };
    let hw = HostValue::F32 {
        shape: vec![256, 256],
        data: w.clone(),
    };
    let out = eng
        .run("qgemm__nvfp4__256", &[hx, hw])
        .expect("qgemm artifact");
    let y = out[0].f32s().unwrap();

    // Rust reference: quantize x along rows, w along cols, then matmul.
    use metis::tensor::Matrix;
    let xm = Matrix::from_f32(256, 256, &x);
    let wm = Matrix::from_f32(256, 256, &w);
    let xq = formats::quantize_matrix_along(Format::Nvfp4, &xm, 1);
    let wq = formats::quantize_matrix_along(Format::Nvfp4, &wm, 0);
    let want = xq.matmul(&wq);

    let mut max_rel = 0f64;
    for (i, &got) in y.iter().enumerate() {
        let w_ = want.data[i];
        let rel = ((got as f64) - w_).abs() / w_.abs().max(1.0);
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-3, "qgemm max rel err {max_rel}");
}

#[test]
fn nano_train_step_runs_and_learns() {
    let Some(eng) = engine() else { return };
    let name = "train_step__nano__nvfp4_metis__b8";
    let spec = eng.manifest.artifact(name).expect("spec").clone();
    let params_key = spec.params_key.clone().unwrap();
    let params = eng.load_params(&params_key).expect("params");
    let n = params.len();

    // m/v zero states shaped like params.
    let zeros: Vec<HostValue> = params
        .iter()
        .map(|p| HostValue::F32 {
            shape: p.shape().to_vec(),
            data: vec![0.0; p.shape().iter().product()],
        })
        .collect();

    let batch = spec.batch.unwrap();
    let seq = eng.manifest.models["nano"].seq_len;
    let vocab = i32::try_from(eng.manifest.models["nano"].vocab).expect("vocab fits i32");
    let mut rng = Rng::new(0);

    let mut state: Vec<HostValue> = params
        .iter()
        .chain(zeros.iter())
        .chain(zeros.iter())
        .cloned()
        .collect();

    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..40 {
        // Learnable pattern: arithmetic token sequences.
        let mut toks = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = i32::try_from(rng.below(vocab as u64)).expect("draw below vocab");
            for t in 0..=seq {
                let t = i32::try_from(t).expect("seq fits i32");
                toks.push((start + 3 * t).rem_euclid(vocab));
            }
        }
        let mut inputs = state.clone();
        inputs.push(HostValue::I32 {
            shape: vec![batch, seq + 1],
            data: toks,
        });
        inputs.push(HostValue::scalar_i32(step));
        inputs.push(HostValue::scalar_i32(42));
        // short warmup, as the coordinator's schedule would provide
        let lr = 1e-2 * (step as f32 / 5.0).min(1.0);
        inputs.push(HostValue::scalar_f32(lr));
        let outs = eng.run(name, &inputs).expect("train step");
        assert_eq!(outs.len(), 3 * n + 2);
        let loss = outs[3 * n].scalar().unwrap();
        assert!(loss.is_finite(), "step {step} loss {loss}");
        if step == 0 {
            first = loss;
        }
        last = loss;
        state = outs[..3 * n].to_vec();
    }
    assert!(
        last < first * 0.8,
        "loss did not drop: {first} -> {last}"
    );
}

#[test]
fn decompose_artifact_invariants() {
    // Regression guard for the old-XLA while-loop miscompilation (see
    // python linalg.jacobi_eigh docstring): exact mathematical
    // invariants of D = P diag(t) Qᵀ + resid, checked on the runtime
    // the Rust coordinator actually uses.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(3);
    let d: Vec<f32> = (0..256 * 96).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let om: Vec<f32> = (0..96 * 10).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let outs = eng
        .run(
            "decompose__256x96",
            &[
                HostValue::F32 {
                    shape: vec![256, 96],
                    data: d.clone(),
                },
                HostValue::F32 {
                    shape: vec![96, 10],
                    data: om,
                },
            ],
        )
        .expect("decompose artifact");
    use metis::tensor::Matrix;
    let p = Matrix::from_f32(256, 10, outs[0].f32s().unwrap());
    let t = outs[1].f32s().unwrap();
    let qt = Matrix::from_f32(10, 96, outs[2].f32s().unwrap());
    let resid = Matrix::from_f32(256, 96, outs[3].f32s().unwrap());
    let dm = Matrix::from_f32(256, 96, &d);

    // (1) exact reconstruction: P diag(t) Qᵀ + resid == D
    let tv: Vec<f64> = t.iter().map(|&x| x as f64).collect();
    let rec = p.scale_cols(&tv).matmul(&qt).add(&resid);
    let err = rec.sub(&dm).frob_norm() / dm.frob_norm();
    assert!(err < 1e-5, "reconstruction err {err}");

    // (2) P orthonormal
    let ptp = p.transpose().matmul(&p);
    for i in 0..10 {
        for j in 0..10 {
            let want = if i == j { 1.0 } else { 0.0 };
            assert!((ptp.at(i, j) - want).abs() < 1e-4, "PᵀP[{i},{j}]");
        }
    }

    // (3) Qᵀ rows unit norm; (4) resid ⊥ P; (5) Σt² == ‖D−resid‖²_F
    for i in 0..10 {
        let n: f64 = qt.row(i).iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((n - 1.0).abs() < 1e-4, "qt row {i} norm {n}");
    }
    let pr = p.transpose().matmul(&resid);
    assert!(pr.abs_max() < 1e-4, "resid not orthogonal: {}", pr.abs_max());
    let t2: f64 = tv.iter().map(|x| x * x).sum();
    let low = dm.sub(&resid).frob_norm().powi(2);
    assert!(
        ((t2 - low) / low).abs() < 1e-4,
        "energy mismatch {t2} vs {low}"
    );
}

#[test]
fn eval_and_features_artifacts_run() {
    let Some(eng) = engine() else { return };
    let params = eng.load_params("nano__fp32").expect("params");
    let batch = 8;
    let seq = eng.manifest.models["nano"].seq_len;

    let mut inputs = params.clone();
    inputs.push(HostValue::I32 {
        shape: vec![batch, seq + 1],
        data: vec![1; batch * (seq + 1)],
    });
    let outs = eng
        .run("eval_loss__nano__fp32__b8", &inputs)
        .expect("eval");
    assert!(outs[0].scalar().unwrap().is_finite());

    let mut inputs = params.clone();
    inputs.push(HostValue::I32 {
        shape: vec![batch, seq],
        data: vec![1; batch * seq],
    });
    let outs = eng
        .run("features__nano__fp32__b8", &inputs)
        .expect("features");
    let d = eng.manifest.models["nano"].d_model;
    assert_eq!(outs[0].shape(), &[batch, d]);
}
