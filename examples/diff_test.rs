//! Dev tool: deterministic 12-step cross-language differential trace.
use metis::runtime::{Engine, HostValue};
fn main() -> anyhow::Result<()> {
    let eng = Engine::new("artifacts")?;
    let name = "train_step__nano__nvfp4_metis__b8";
    let params = eng.load_params("nano__nvfp4_metis")?;
    let n = params.len();
    let zeros: Vec<HostValue> = params.iter().map(|p| HostValue::F32{shape:p.shape().to_vec(), data:vec![0.0;p.shape().iter().product()]}).collect();
    let mut state: Vec<HostValue> = params.iter().chain(zeros.iter()).chain(zeros.iter()).cloned().collect();
    let (batch, seq, vocab) = (8usize, 32usize, 128i32);
    for step in 0..12 {
        let mut toks = Vec::new();
        for b in 0..batch {
            let start = ((b as i32)*17 + step*31) % vocab;
            for t in 0..=seq as i32 { toks.push((start + 3*t).rem_euclid(vocab)); }
        }
        let tok = HostValue::I32{shape:vec![batch,seq+1], data:toks};
        let st = HostValue::scalar_i32(step);
        let sd = HostValue::scalar_i32(42);
        let lr = HostValue::scalar_f32(1e-2*((step as f32)/5.0).min(1.0));
        let mut inputs: Vec<&HostValue> = state.iter().collect();
        inputs.push(&tok); inputs.push(&st); inputs.push(&sd); inputs.push(&lr);
        let outs = eng.run(name, &inputs)?;
        println!("step {step} loss {:.6} gnorm {:.4}", outs[3*n].scalar()?, outs[3*n+1].scalar()?);
        state = outs; state.truncate(3*n);
    }
    Ok(())
}
