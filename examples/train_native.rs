//! The native W4A4G4 training loop in ~60 lines (no artifacts, no
//! PJRT): pack a synthetic model once through the Eq. 3 split, then
//! watch the per-step Eq. 6 gradient splits + §3.2 adaptive LR +
//! sub-distribution quantization drive the loss down — and verify the
//! loss curve is bit-identical across thread counts.
//!
//! Run: `cargo run --release --example train_native [-- --fmt paper_fp4
//!       --strategy sparse_sample --steps 40 --threads 4]`

use anyhow::Result;
use metis::cli::Args;
use metis::formats::Format;
use metis::metis::{
    train_native, DecompStrategy, GradStepConfig, MetisQuantConfig, NativeTrainConfig, Optim,
};

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let fmt = Format::from_name(&args.str("fmt", "paper_fp4"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fmt"))?;
    let strategy = DecompStrategy::from_name(&args.str("strategy", "sparse_sample"))
        .ok_or_else(|| anyhow::anyhow!("unknown --strategy"))?;
    let cfg = NativeTrainConfig {
        n_layers: args.usize("layers", 2)?,
        d_model: args.usize("d-model", 48)?,
        steps: args.usize("steps", 40)?,
        threads: args.usize("threads", 4)?,
        quant: MetisQuantConfig {
            fmt,
            strategy,
            ..MetisQuantConfig::default()
        },
        grad: GradStepConfig {
            fmt,
            ..GradStepConfig::default()
        },
        optim: Optim::from_name(&args.str("optim", "sgd"))
            .ok_or_else(|| anyhow::anyhow!("unknown --optim"))?,
        ..NativeTrainConfig::default()
    };

    println!(
        "native W4A4G4 loop: {} blocks @ d_model {}, {} steps, fmt {}, strategy {}, {} threads",
        cfg.n_layers, cfg.d_model, cfg.steps, fmt.name(), strategy.name(), cfg.threads
    );
    let res = train_native(&cfg)?;
    for rep in res.reports.iter().step_by(5.max(cfg.steps / 8)) {
        let l0 = &rep.layers[0];
        println!(
            "  step {:>3}  loss {:>9.4}  lr {:.2e}  |  {}: σ₁ {:.3e} amp {:.2} captured {:.0}% split {:.1} ms",
            rep.step, rep.loss, rep.lr, l0.name, l0.t1, l0.amp_mean,
            100.0 * l0.captured, l0.split_ms
        );
    }
    println!(
        "loss {:.4} → {:.4} ({:.1}× lower) in {:.0} ms on {} threads",
        res.first_loss(),
        res.final_loss(),
        res.first_loss() / res.final_loss().max(1e-12),
        res.wall_ms,
        res.threads
    );

    // Determinism spot-check: one extra single-threaded step-for-step run.
    let res1 = train_native(&NativeTrainConfig { threads: 1, ..cfg })?;
    let same = res.losses() == res1.losses();
    println!("thread-count invariance: {}", if same { "bit-identical" } else { "FAILED" });
    anyhow::ensure!(same, "loss curves diverged across thread counts");
    Ok(())
}
