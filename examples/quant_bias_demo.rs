//! §2.3 interactive demo: how block-wise quantization is biased against
//! small magnitudes, and how the Metis spectral split removes the bias.
//!
//! Pure Rust (no artifacts needed): builds an anisotropic matrix with a
//! planted power-law spectrum, quantizes it directly vs via the split
//! W = U_k S_k V_kᵀ + W_R, and prints the §2.3 bias metrics for both.
//!
//! Run: `cargo run --release --example quant_bias_demo [-- --fmt mxfp4]`

use anyhow::Result;
use metis::cli::Args;
use metis::formats::{self, blockq::quant_stats, Format};
use metis::linalg::{householder_qr, jacobi_svd, rsvd::spectral_split};
use metis::spectral;
use metis::tensor::Matrix;
use metis::util::prng::Rng;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let fmt = Format::from_name(&args.str("fmt", "mxfp4"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fmt"))?;
    let n = args.usize("n", 128)?;
    let power = args.f64("power", 1.4)?;

    let mut rng = Rng::new(0);
    let spectrum: Vec<f64> = (1..=n).map(|i| 10.0 * (i as f64).powf(-power)).collect();
    let q1 = householder_qr(&Matrix::gaussian(&mut rng, n, n, 1.0)).q;
    let q2 = householder_qr(&Matrix::gaussian(&mut rng, n, n, 1.0)).q;
    let w = q1.scale_cols(&spectrum).matmul(&q2.transpose());

    println!("anisotropic {n}x{n}, σᵢ ∝ i^-{power}, format {}", fmt.name());
    let (_, elbow) = spectral::elbow_fraction(&spectrum);
    println!("  elbow fraction {:.1}%  (paper Fig.1: 1.9–2.4%)", 100.0 * elbow);

    // --- direct block quantization ------------------------------------------
    let qd = formats::quantize_matrix_along(fmt, &w, 0);
    let sd = quant_stats(&w, &qd);
    println!("\n-- direct {} --", fmt.name());
    println!("  rel Frobenius error   {:.4}", sd.rel_frob_err);
    println!("  underflow (clip to 0) {:.2}%", 100.0 * sd.underflow_frac);
    println!(
        "  rel err small-decile {:.3} vs large-decile {:.3}  ({}x bias)",
        sd.decile_rel_err[0],
        sd.decile_rel_err[9],
        (sd.decile_rel_err[0] / sd.decile_rel_err[9].max(1e-9)) as i64
    );
    let sv_d = jacobi_svd(&qd).s;
    let errs_d = spectral::sigma_rel_errors(&spectrum, &sv_d);

    // --- Metis split: quantize U, Vᵀ, W_R; keep S exact ----------------------
    let k = (n as f64 * 0.1).ceil() as usize;
    let split = spectral_split(&w, k, &mut rng);
    let uq = formats::quantize_matrix_along(fmt, &split.svd.u, 0);
    let vq = formats::quantize_matrix_along(fmt, &split.svd.v, 0);
    let rq = formats::quantize_matrix_along(fmt, &split.residual, 0);
    let rec = uq
        .scale_cols(&split.svd.s)
        .matmul(&vq.transpose())
        .add(&rq);
    let sm = quant_stats(&w, &rec);
    println!("\n-- Metis split (k = {k}) + {} on factors --", fmt.name());
    println!("  rel Frobenius error   {:.4}", sm.rel_frob_err);
    println!("  underflow (clip to 0) {:.2}%", 100.0 * sm.underflow_frac);
    println!(
        "  factor ranges: |U|max {:.3}, |V|max {:.3} vs |W|max {:.3} (Fig. 5)",
        split.svd.u.abs_max(),
        split.svd.v.abs_max(),
        w.abs_max()
    );
    let sv_m = jacobi_svd(&rec).s;
    let errs_m = spectral::sigma_rel_errors(&spectrum, &sv_m);

    println!("\n-- σ relative error by rank (Fig. 4B shape) --");
    println!("  rank      direct    metis");
    for r in [0usize, 2, 8, n / 4, n / 2, n - 2] {
        println!("  {:>4}    {:>7.4}   {:>7.4}", r, errs_d[r], errs_m[r]);
    }
    println!(
        "\n  tail-half mean: direct {:.4} vs metis {:.4}",
        errs_d[n / 2..].iter().sum::<f64>() / (n / 2) as f64,
        errs_m[n / 2..].iter().sum::<f64>() / (n / 2) as f64
    );
    Ok(())
}
