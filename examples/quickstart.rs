//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. open the artifact store (built once by `make artifacts`),
//! 2. run the L1 Pallas quantizer artifact from Rust and cross-check it
//!    against the native Rust codecs,
//! 3. train a nano model for a handful of steps through the AOT
//!    train_step artifact,
//! 4. run a spectral analysis on one of its weight matrices.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use metis::bench::artifacts_dir;
use metis::coordinator::{ExperimentConfig, Trainer};
use metis::formats::{self, Format};
use metis::linalg::jacobi_svd;
use metis::runtime::{Engine, HostValue};
use metis::spectral;
use metis::tensor::Matrix;
use metis::util::prng::Rng;

fn main() -> Result<()> {
    let engine = Engine::new(artifacts_dir())?;
    println!(
        "engine up: platform={}, {} artifacts",
        engine.client.platform_name(),
        engine.manifest.artifacts.len()
    );

    // --- 1. Pallas kernel from Rust + cross-language check ---------------
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..256 * 256).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let out = engine.run(
        "quantize__nvfp4__256x256",
        &[HostValue::F32 {
            shape: vec![256, 256],
            data: data.clone(),
        }],
    )?;
    let q_pallas = out[0].f32s()?;
    let q_rust: Vec<f32> = data
        .chunks(256)
        .flat_map(|row| formats::quantize_block(Format::Nvfp4, row))
        .collect();
    let max_err = q_pallas
        .iter()
        .zip(&q_rust)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pallas-vs-rust NVFP4 quantizer: max |Δ| = {max_err:.2e}");

    // --- 2. Train a nano model through the coordinator --------------------
    let mut cfg = ExperimentConfig::default();
    cfg.model = "nano".into();
    cfg.mode = "nvfp4_metis".into();
    cfg.steps = 40;
    cfg.lr = 1e-2;
    cfg.warmup = 5;
    cfg.name = "quickstart".into();
    cfg.out_dir = std::env::temp_dir()
        .join("metis_quickstart")
        .to_string_lossy()
        .into_owned();
    let mut trainer = Trainer::new(&engine, cfg)?;
    println!("\ntraining nano/nvfp4_metis for 40 steps (first step compiles)...");
    let res = trainer.train()?;
    println!(
        "loss {:.3} -> {:.3}; held-out {:.3}; {:.0} ms/step",
        res.losses[0],
        res.final_train_loss(),
        res.test_loss,
        res.step_ms_mean
    );

    // --- 3. Spectral analysis of a trained factor -------------------------
    // The Metis parameterization stores U_k S_k V_kᵀ + W_R; inspect W_R of
    // the first-layer FFN input projection.
    let idx = trainer
        .param_names
        .iter()
        .position(|n| n == "layers.wfc.wr")
        .expect("decomposed layout exposes layers.wfc.wr");
    let hv = &trainer.params()[idx];
    let shape = hv.shape(); // (L, d, h) stacked — take layer 0
    let (d, h) = (shape[1], shape[2]);
    let slice = &hv.f32s()?[..d * h];
    let w = Matrix::from_f32(d, h, slice);
    let svd = jacobi_svd(&w);
    let (k, frac) = spectral::elbow_fraction(&svd.s);
    println!(
        "\nresidual W_R of layer-0 wfc: {d}x{h}, σ₁={:.4}, elbow k*={k} ({:.1}% of rank)",
        svd.s[0],
        100.0 * frac
    );
    println!("\nquickstart OK");
    Ok(())
}
