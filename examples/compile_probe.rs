// probe: compile times per artifact
use metis::runtime::Engine;
fn main() {
    let eng = Engine::new("artifacts").unwrap();
    for name in std::env::args().skip(1) {
        let t = std::time::Instant::now();
        eng.load(&name).unwrap();
        println!("{name}: {:.1}s", t.elapsed().as_secs_f64());
    }
}
