//! End-to-end driver (DESIGN.md deliverable (b) / EXPERIMENTS.md §E2E):
//! trains the "small" GPT-2 stand-in (~1M params) for a couple hundred
//! steps under full W4A4G4 NVFP4 + Metis via the AOT artifacts, with the
//! fp32 and direct-FP4 baselines, then probes the six GLUE-shaped tasks.
//!
//! Uses the shared run store, so results line up with (and are reused by)
//! the bench suite; pass --fresh to force retraining here.
//!
//! Run: `cargo run --release --example train_fp4_e2e [-- --steps N]
//!       [--model small] [--modes fp32,nvfp4_direct,nvfp4_metis] [--fresh]`

use anyhow::Result;
use metis::bench::{artifacts_dir, fmt_f, fmt_pct, reports_dir, Table};
use metis::cli::Args;
use metis::coordinator::runstore::{bench_config, canonical_steps};
use metis::coordinator::RunStore;
use metis::runtime::Engine;

const TASKS: [&str; 6] = ["CoLA", "SST-2", "MRPC", "MNLI", "QNLI", "RTE"];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let model = args.str("model", "small");
    let steps = args.usize("steps", canonical_steps(&model))?;
    let modes = args.str("modes", "fp32,nvfp4_direct,nvfp4_metis");
    let engine = Engine::new(artifacts_dir())?;
    let store = if args.switch("fresh") {
        RunStore::open(std::env::temp_dir().join("metis_e2e_fresh"))?
    } else {
        RunStore::default_store()?
    };

    let mut table = Table::new(
        &format!("E2E: {model} / {steps} steps (paper headline: Metis-FP4 tracks FP32)"),
        &["mode", "first loss", "final loss", "test loss", "ms/step", "avg probe acc"],
    );

    for mode in modes.split(',') {
        println!("\n=== {model}/{mode} ===");
        let cfg = bench_config(&model, mode, steps);
        let rec = store.get_or_run(&engine, &cfg, true)?;
        println!(
            "  final {:.4}  test {:.4}  {:.0} ms/step (compile {:.0}s){}",
            rec.final_train_loss(),
            rec.test_loss,
            rec.step_ms_mean,
            rec.compile_ms / 1e3,
            if rec.diverged { "  [DIVERGED]" } else { "" }
        );
        for t in TASKS {
            if let Some(a) = rec.probes.get(t) {
                println!("  {t:<6} {:.1}%", 100.0 * a);
            }
        }
        table.row(vec![
            mode.to_string(),
            fmt_f(rec.losses.first().copied().unwrap_or(f32::NAN) as f64, 4),
            if rec.diverged {
                "NaN".into()
            } else {
                fmt_f(rec.final_train_loss() as f64, 4)
            },
            fmt_f(rec.test_loss as f64, 4),
            fmt_f(rec.step_ms_mean, 0),
            fmt_pct(rec.avg_probe_acc(&TASKS)),
        ]);
    }

    table.print();
    table.write_csv(reports_dir().join("e2e_fp4.csv").to_str().unwrap())?;
    println!("\nreport: reports/e2e_fp4.csv");
    Ok(())
}
