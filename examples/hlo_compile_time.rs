//! Measure PJRT compile time of an arbitrary HLO text file.
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for path in std::env::args().skip(1) {
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let _exe = client.compile(&comp)?;
        println!("{path}: {:.1}s", t.elapsed().as_secs_f64());
    }
    Ok(())
}
