//! Quickstart for the pure-Rust Metis engine (no artifacts needed):
//! split an anisotropic weight matrix (Eq. 3), quantize each
//! sub-distribution (Eq. 5), split a synthetic gradient (Eq. 6) with
//! the §3.2 adaptive spectral LR, then sweep a small synthetic model
//! through the layer-sharded pipeline.
//!
//! Run: `cargo run --release --example metis_quantize [-- --fmt mxfp4
//!       --strategy sparse_sample --threads 4]`

use anyhow::Result;
use metis::cli::Args;
use metis::formats::Format;
use metis::linalg::jacobi_svd;
use metis::metis::{
    gradient_split, pipeline, quantizer, weight_split, DecompStrategy, MetisQuantConfig,
    PipelineConfig,
};
use metis::util::prng::Rng;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let fmt = Format::from_name(&args.str("fmt", "nvfp4"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fmt"))?;
    let strategy = DecompStrategy::from_name(&args.str("strategy", "sparse_sample"))
        .ok_or_else(|| anyhow::anyhow!("unknown --strategy"))?;
    let threads = args.usize("threads", 4)?;
    let mut rng = Rng::new(0);

    // --- 1. Eq. 3 split + Eq. 5 sub-distribution quantization ------------
    let w = pipeline::planted_powerlaw(&mut rng, 128, 96, 1.5);
    let split = weight_split(&w, 12, strategy, &mut rng);
    println!(
        "W 128x96: σ₁ {:.3}, split k=12 residual carries {:.1}% of ‖W‖",
        split.svd.s[0],
        100.0 * split.residual.frob_norm() / w.frob_norm()
    );
    let reference = jacobi_svd(&w).s;
    let metis_q = quantizer::quantize_split(&split, fmt);
    let direct_q = quantizer::quantize_direct(&w, fmt);
    let (sig_m, tail_m) = quantizer::sigma_distortion(&reference, &metis_q);
    let (sig_d, tail_d) = quantizer::sigma_distortion(&reference, &direct_q);
    println!(
        "{}: σ-distortion metis {:.4} (tail {:.4}) vs direct {:.4} (tail {:.4})",
        fmt.name(),
        sig_m,
        tail_m,
        sig_d,
        tail_d
    );

    // --- 2. Eq. 6 gradient split + §3.2 adaptive spectral LR -------------
    let d = pipeline::planted_powerlaw(&mut rng, 64, 96, 1.5).scale(1e-4);
    let dec = gradient_split(&d, 8, 1, true, &mut rng);
    let rec_err = dec.reconstruct(false).sub(&d).frob_norm() / d.frob_norm();
    println!(
        "\ngradient split j=8: exact reconstruction err {rec_err:.2e}; \
         t̃/t amplification head→tail: {:.2} → {:.2}",
        dec.t_adapt[0] / dec.t[0].max(1e-300),
        dec.t_adapt[7] / dec.t[7].max(1e-300)
    );

    // --- 3. Layer-sharded pipeline over a synthetic model ----------------
    let cfg = PipelineConfig {
        quant: MetisQuantConfig {
            fmt,
            strategy,
            rho: 0.1,
            max_rank: 32,
        },
        threads,
        measure_sigma: true,
        sigma_dim_cap: 128,
        seed: 0,
        ..PipelineConfig::default()
    };
    let res = pipeline::run(pipeline::synthetic_model(2, 48, 0), &cfg)?;
    let (m, dd) = res.mean_sigma_err();
    println!(
        "\npipeline: {} layers in {:.0} ms on {} threads; mean σ-distortion {:.4} vs {:.4} direct",
        res.reports.len(),
        res.wall_ms,
        res.threads,
        m,
        dd
    );
    println!("\nmetis_quantize OK");
    Ok(())
}
