//! The paper's §2.1–2.2 analysis pipeline on live checkpoints:
//! trains a model briefly, then measures (a) singular spectra + elbow
//! fractions (Fig. 1), (b) gradient singular alignment (Fig. 2),
//! (c) spectral-energy → variance → Popoviciu range bound (§2.2).
//!
//! Run: `cargo run --release --example anisotropy_analysis [-- --steps 120]`

use anyhow::Result;
use metis::bench::artifacts_dir;
use metis::cli::Args;
use metis::coordinator::{ExperimentConfig, Trainer};
use metis::linalg::jacobi_svd;
use metis::runtime::{Engine, HostValue};
use metis::spectral;
use metis::tensor::Matrix;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let steps = args.usize("steps", 120)?;
    let engine = Engine::new(artifacts_dir())?;

    let mut cfg = ExperimentConfig::default();
    cfg.name = "aniso".into();
    cfg.model = args.str("model", "tiny");
    cfg.mode = "fp32".into();
    cfg.steps = steps;
    cfg.lr = 1e-2;
    cfg.warmup = steps / 10;
    let model = cfg.model.clone();
    let mut trainer = Trainer::new(&engine, cfg.clone())?;
    println!("training {model}/fp32 for {steps} steps...");
    let res = trainer.train()?;
    println!("final loss {:.4}\n", res.final_train_loss());

    // --- Fig. 2-style: W, X, G of the deepest FFN via the analysis artifact
    let analysis = engine
        .manifest
        .name_for("analysis", &model, "fp32", 8);
    let seq = engine.manifest.models[&model].seq_len;
    let tokens = {
        use metis::data::corpus::{Corpus, CorpusConfig};
        use metis::data::BatchIterator;
        let c = Corpus::new(CorpusConfig::new(engine.manifest.models[&model].vocab, 7));
        BatchIterator::new(&c, 8, seq, 1).next_batch()
    };
    let tok_hv = HostValue::I32 {
        shape: vec![8, seq + 1],
        data: tokens,
    };
    let mut inputs: Vec<&HostValue> = trainer.params().iter().collect();
    inputs.push(&tok_hv);
    let outs = engine.run(&analysis, &inputs)?;
    let names = ["w_fc", "g_fc", "x_fc", "w_key", "g_key"];
    let mats: Vec<Matrix> = outs
        .iter()
        .map(|hv| {
            let s = hv.shape();
            Matrix::from_f32(s[0], s[1], hv.f32s().unwrap())
        })
        .collect();

    println!("== singular spectra + elbow fractions (Fig. 1 analogue) ==");
    for (name, m) in names.iter().zip(&mats) {
        let svd = jacobi_svd(m);
        let (k, f) = spectral::elbow_fraction(&svd.s);
        let e10 = spectral::energy_fraction(&svd.s, (svd.s.len() / 10).max(1));
        println!(
            "  {name:<6} {:>4}x{:<4} σ₁={:>8.4}  elbow k*={k:<3} ({:.1}%)  top-10% energy {:.1}%",
            m.rows,
            m.cols,
            svd.s[0],
            100.0 * f,
            100.0 * e10
        );
    }

    println!("\n== gradient singular alignment |aᵢ| = |uᵢᵀ G vᵢ| (Fig. 2) ==");
    for (wn, gn) in [("w_fc", "g_fc"), ("w_key", "g_key")] {
        let wi = names.iter().position(|n| n == &wn).unwrap();
        let gi = names.iter().position(|n| n == &gn).unwrap();
        let svd = jacobi_svd(&mats[wi]);
        let align = spectral::gradient_alignment(&svd, &mats[gi]);
        print!("  {wn:<6} |a| at σ-rank 0,2,8,32: ");
        for r in [0usize, 2, 8, 32] {
            if r < align.len() {
                print!("{:.2e}  ", align[r].abs());
            }
        }
        // Spearman-ish check: top-quarter mean vs bottom-quarter mean.
        let q = align.len() / 4;
        let top: f64 = align[..q].iter().map(|a| a.abs()).sum::<f64>() / q as f64;
        let bot: f64 = align[3 * q..].iter().map(|a| a.abs()).sum::<f64>()
            / (align.len() - 3 * q) as f64;
        println!("  top/bottom quartile ratio {:.1}x", top / bot.max(1e-18));
    }

    println!("\n== variance / range bound (§2.2, Eq. 1–2) ==");
    for (name, m) in names.iter().zip(&mats).take(3) {
        let svd = jacobi_svd(m);
        let (var, bound, actual) = spectral::popoviciu_check(m, &svd.s);
        println!(
            "  {name:<6} Var={var:.3e}  2√Var={bound:.3e} ≤ range={actual:.3e}  kurtosis={:.1}",
            metis::tensor::hist::kurtosis(&m.data)
        );
    }
    Ok(())
}
